//! GEMV microbenchmarks: f32 baseline vs packed-ternary W1.58A8 kernels
//! (byte-decode, activation-LUT and runtime-dispatched SIMD
//! generations) at the real model dimensions. Regenerates the
//! kernel-level half of the paper's CPU speedup claim (~2.65x at 16
//! threads; single-core here). The LUT timing includes its per-call
//! table build — the unamortized worst case; the engine shares one
//! build across Q/K/V or gate/up. On hosts without AVX2/NEON the SIMD
//! rows time the (bitwise-identical) scalar fallback.

// Bench/example crate roots sit outside src/lib.rs, so the Cargo.toml
// clippy deny-list (unwrap_used & co.) is re-allowed here: panicking on
// bad setup is the right behavior for a demo or harness, as in tests.
#![allow(clippy::unwrap_used, clippy::indexing_slicing, clippy::float_cmp)]

use bitnet_distill::engine::gemv::{gemv_f32, gemv_ternary};
use bitnet_distill::engine::lut::{lut_gemv, LutScratch};
use bitnet_distill::engine::simd::{simd_gemv, ternary_simd_available};
use bitnet_distill::engine::{act_quant_i8, TernaryMatrix};
use bitnet_distill::substrate::bench::bench;
use bitnet_distill::substrate::Rng;

fn main() {
    println!("# gemv: f32 vs ternary at model dims (out x in)");
    println!("# ternary_simd_available={}", ternary_simd_available());
    // (out, in) pairs: tiny/small/base attention + FFN shapes
    for (n, k) in [(128, 128), (384, 128), (256, 256), (768, 256), (384, 384), (1152, 384), (384, 1152)] {
        let mut rng = Rng::new(7);
        let mut w = vec![0.0f32; n * k];
        rng.fill_normal(&mut w, 0.05);
        let mut x = vec![0.0f32; k];
        rng.fill_normal(&mut x, 1.0);

        // f32: transpose-free [out, in] layout (the engine's layout)
        let mut y = vec![0.0f32; n];
        let rf = bench(&format!("gemv_f32_{n}x{k}"), || {
            gemv_f32(&w, n, k, &x, &mut y);
            y[0]
        });

        // ternary: packed, with per-call act quant (as the engine does)
        let tm = TernaryMatrix::from_xw_f32(&w, k, n); // note: treats w as [in,out]; dims ok for timing
        let mut q = vec![0i8; k];
        let mut yt = vec![0.0f32; tm.rows];
        let rt = bench(&format!("gemv_tern_{}x{k}", tm.rows), || {
            let gamma = act_quant_i8(&x[..tm.cols], &mut q);
            gemv_ternary(&tm, &q, gamma, &mut yt);
            yt[0]
        });

        // activation-LUT generation: per-4-activation-group tables built
        // per call (act quant + table build + one load/add per byte)
        let mut lscratch = LutScratch::for_dims(tm.cols, 1);
        let mut yl = vec![0.0f32; tm.rows];
        let rl = bench(&format!("gemv_lut_{}x{k}", tm.rows), || {
            let gamma = act_quant_i8(&x[..tm.cols], &mut q);
            let table = lscratch.build(&q);
            lut_gemv(&tm, table, gamma, &mut yl);
            yl[0]
        });

        // SIMD generation: in-register nibble decode on the same
        // pre-packed matrix (per-call act quant, like the byte row)
        let mut ys = vec![0.0f32; tm.rows];
        let rs = bench(&format!("gemv_simd_{}x{k}", tm.rows), || {
            let gamma = act_quant_i8(&x[..tm.cols], &mut q);
            simd_gemv(&tm, &q, gamma, &mut ys);
            ys[0]
        });

        let flops = 2.0 * n as f64 * k as f64;
        rf.report(&format!(
            "gflops={:.2} bytes_per_weight=4",
            flops / rf.mean_ns
        ));
        rt.report(&format!(
            "gflops_equiv={:.2} bytes_per_weight=0.25 speedup_vs_f32={:.2}x",
            flops / rt.mean_ns,
            rf.mean_ns / rt.mean_ns
        ));
        rl.report(&format!(
            "gflops_equiv={:.2} bytes_per_weight=0.25 speedup_vs_f32={:.2}x \
             speedup_vs_byte={:.2}x",
            flops / rl.mean_ns,
            rf.mean_ns / rl.mean_ns,
            rt.mean_ns / rl.mean_ns
        ));
        rs.report(&format!(
            "gflops_equiv={:.2} bytes_per_weight=0.25 speedup_vs_f32={:.2}x \
             speedup_vs_lut={:.2}x",
            flops / rs.mean_ns,
            rf.mean_ns / rs.mean_ns,
            rl.mean_ns / rs.mean_ns
        ));
    }
}
