//! Data-pipeline throughput: generators and tokenizer must never be the
//! bottleneck of a training step (steps are ~1s; batches must be ~us).

// Bench/example crate roots sit outside src/lib.rs, so the Cargo.toml
// clippy deny-list (unwrap_used & co.) is re-allowed here: panicking on
// bad setup is the right behavior for a demo or harness, as in tests.
#![allow(clippy::unwrap_used, clippy::indexing_slicing, clippy::float_cmp)]

use bitnet_distill::data::{CorpusBatcher, CorpusStream, Task, TaskGen, Tokenizer};
use bitnet_distill::substrate::bench::bench;

fn main() {
    let tok = Tokenizer::new(1024);

    let stream = CorpusStream::new(&tok, 128, 1);
    let mut cb = CorpusBatcher::new(stream, 8, 128);
    let r = bench("corpus_batch_8x128", || cb.next_batch());
    r.report(&format!("tokens_per_s={:.0}", r.throughput(8.0 * 128.0)));

    for task in [Task::Mnli, Task::Qnli, Task::Sst2, Task::Cnndm] {
        let gen = TaskGen::new(task, &tok, 128);
        let mut rng = bitnet_distill::substrate::Rng::new(3);
        let r = bench(&format!("taskgen_{}", task.name()), || gen.example(&mut rng));
        r.report(&format!("examples_per_s={:.0}", r.throughput(1.0)));
    }

    let words: Vec<&str> = "the brave farmer feeds the horse near the meadow".split(' ').collect();
    let r = bench("tokenize_9w", || tok.encode(&words));
    r.report(&format!("words_per_s={:.0}", r.throughput(9.0)));
}
