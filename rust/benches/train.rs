//! Native train-step throughput: tokens/s and step-time p50/p95 for the
//! tape-based CE and distillation steps. Needs NO artifacts — this is
//! the `--backend native` hot path. Emits reports/BENCH_train.json and
//! appends `kind:"train"` rows to reports/results.jsonl (rendered by
//! `bitdistill report`).

// Bench/example crate roots sit outside src/lib.rs, so the Cargo.toml
// clippy deny-list (unwrap_used & co.) is re-allowed here: panicking on
// bad setup is the right behavior for a demo or harness, as in tests.
#![allow(clippy::unwrap_used, clippy::indexing_slicing, clippy::float_cmp)]

use std::time::Instant;

use bitnet_distill::bench::{append_train_results, write_train_report, TrainRow};
use bitnet_distill::data::{CorpusBatcher, CorpusStream, Tokenizer};
use bitnet_distill::params::ParamStore;
use bitnet_distill::runtime::ModelSpec;
use bitnet_distill::substrate::Rng;
use bitnet_distill::train::NativeTrainer;

fn main() -> anyhow::Result<()> {
    let (batch, seq) = (2usize, 32usize);
    let tok = Tokenizer::new(1024);
    let mut rows = Vec::new();

    for size in ["micro", "tiny"] {
        // --- CE (bitnet_train analog: QAT student) ---
        let spec = ModelSpec::synthetic_with(size, true, "absmean")?;
        let mut rng = Rng::new(1);
        let params = ParamStore::init(&spec, &mut rng);
        let mut tr = NativeTrainer::new(spec, params);
        let stream = CorpusStream::new(&tok, seq, 2);
        let mut batches = CorpusBatcher::new(stream, batch, seq);
        let warm = batches.next_batch();
        tr.train_step(&warm, 1e-3)?;
        let steps = 6usize;
        let mut ms = Vec::with_capacity(steps);
        for _ in 0..steps {
            let b = batches.next_batch();
            let t0 = Instant::now();
            tr.train_step(&b, 1e-3)?;
            ms.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        let row = TrainRow::from_step_times("native", size, "ce", batch * seq, &ms);
        println!("{}", row.render());
        rows.push(row);

        // --- distill (stage-3 analog: CE + LD + AD vs FP teacher) ---
        let tspec = ModelSpec::synthetic_with(size, false, "none")?;
        let mut rng = Rng::new(3);
        let teacher = ParamStore::init(&tspec, &mut rng);
        let sspec = ModelSpec::synthetic_with(size, true, "absmean")?;
        let mut rng = Rng::new(4);
        let sparams = ParamStore::init(&sspec, &mut rng);
        let mut tr = NativeTrainer::new(sspec, sparams).with_teacher(tspec);
        let dl = tr.spec.config.n_layers as i32 - 2;
        let warm = batches.next_batch();
        tr.distill_step(&teacher, &warm, 1e-3, 10.0, 1e2, dl)?;
        let steps = 4usize;
        let mut ms = Vec::with_capacity(steps);
        for _ in 0..steps {
            let b = batches.next_batch();
            let t0 = Instant::now();
            tr.distill_step(&teacher, &b, 1e-3, 10.0, 1e2, dl)?;
            ms.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        let row = TrainRow::from_step_times("native", size, "distill", batch * seq, &ms);
        println!("{}", row.render());
        rows.push(row);
    }

    write_train_report(&rows, "reports/BENCH_train.json")?;
    append_train_results(&rows, "reports/results.jsonl")?;
    println!("wrote reports/BENCH_train.json");
    Ok(())
}
