//! HLO train-step latency per size — the L2/L3 boundary cost that gates
//! every experiment budget (EXPERIMENTS.md §Perf).

// Bench/example crate roots sit outside src/lib.rs, so the Cargo.toml
// clippy deny-list (unwrap_used & co.) is re-allowed here: panicking on
// bad setup is the right behavior for a demo or harness, as in tests.
#![allow(clippy::unwrap_used, clippy::indexing_slicing, clippy::float_cmp)]

use bitnet_distill::data::{CorpusBatcher, CorpusStream, Tokenizer};
use bitnet_distill::params::ParamStore;
use bitnet_distill::pipeline::{stages, Trainer};
use bitnet_distill::runtime::Runtime;
use bitnet_distill::substrate::Rng;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("SKIP train_step bench: run `make artifacts` first");
        return Ok(());
    }
    let rt = Runtime::open("artifacts")?;
    let tok = Tokenizer::new(rt.manifest.vocab);
    for (size, steps) in [("tiny", 6usize), ("small", 4), ("base", 2)] {
        for (kind, artifact_key) in [
            ("lm", stages::teacher_key(size)),
            ("bitnet", stages::model_key(size, true, "absmean")),
        ] {
            let artifact = format!("{size}_{kind}_train");
            let spec = rt.manifest.model(&artifact_key)?;
            let mut rng = Rng::new(1);
            let params = ParamStore::init(spec, &mut rng);
            let mut tr = Trainer::new(&rt, &artifact, params);
            let stream = CorpusStream::new(&tok, rt.manifest.seq, 2);
            let mut b = CorpusBatcher::new(stream, rt.manifest.batch, rt.manifest.seq);
            let batch = b.next_batch();
            tr.train_step(&batch, 1e-3)?; // warm (includes compile)
            let t0 = Instant::now();
            for _ in 0..steps {
                let batch = b.next_batch();
                tr.train_step(&batch, 1e-3)?;
            }
            let per = t0.elapsed().as_secs_f64() / steps as f64;
            let toks = (rt.manifest.batch * rt.manifest.seq) as f64;
            println!(
                "bench name=train_{size}_{kind} step={per:.3}s tokens_per_s={:.0} n_params={}",
                toks / per,
                spec.n_params
            );
        }
    }
    Ok(())
}
