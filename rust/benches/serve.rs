//! Serving throughput: continuous batching vs sequential decode, f32 vs
//! packed-ternary, at batch sizes 1/4/16 and engine thread counts
//! 1/2/4/8 — the deployment-scale half of the paper's CPU story. Emits
//! reports/BENCH_serve.json (requests/s and p95 per configuration, one
//! row per thread count at max_batch 16, so the parallel speedup curve
//! shows up in `bitdistill report`) and appends the rows to
//! reports/results.jsonl. Outputs are thread-count-invariant (the
//! parallel kernels are bitwise identical to serial); only the
//! throughput and latency columns move.
//!
//! Needs no artifacts: falls back to the synthetic tiny spec with random
//! weights (serving speed/memory do not depend on weight values).

use bitnet_distill::bench as harness;
use bitnet_distill::data::{Task, Tokenizer};

fn main() -> anyhow::Result<()> {
    let n_req: usize = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(64);
    let (f32e, terne) = harness::serving_engines("tiny", "artifacts")?;
    let mut rows = Vec::new();
    for (name, engine) in [("f32", &f32e), ("ternary", &terne)] {
        let tok = Tokenizer::new(engine.cfg.vocab);
        // classification = prefill-heavy; summarization = decode-heavy
        for (task, n, max_new) in [(Task::Mnli, n_req, 0), (Task::Cnndm, n_req / 4, 16)] {
            let reqs = harness::serve_workload(task, &tok, n.max(1), engine.cfg.seq, max_new, 321);
            let seq = harness::serve_sequential(engine, name, task, &reqs);
            println!("{}", seq.render());
            rows.push(seq);
            // batching curve at one thread
            for max_batch in [1usize, 4] {
                let row = harness::serve_batched(engine, name, task, &reqs, max_batch, 256, 1);
                println!("{}", row.render());
                rows.push(row);
            }
            // thread sweep at full batch: the parallel speedup curve.
            // `threads` is the requested pool size; the pool's work
            // floor caps *effective* workers per matmul by its row count
            // (on the tiny shape only the vocab-size LM head fans wide,
            // so high thread counts converge — expected at this scale).
            for threads in [1usize, 2, 4, 8] {
                let row = harness::serve_batched(engine, name, task, &reqs, 16, 256, threads);
                println!("{}", row.render());
                rows.push(row);
            }
        }
    }
    harness::write_serve_report(&rows, "reports/BENCH_serve.json")?;
    harness::append_serve_results(&rows, "reports/results.jsonl")?;
    println!("wrote reports/BENCH_serve.json ({} rows)", rows.len());
    Ok(())
}
