//! Serving throughput: continuous batching vs sequential decode, f32 vs
//! packed-ternary, all three ternary kernel generations (byte-decode,
//! activation-LUT, runtime-dispatched SIMD), at batch sizes
//! 1/4/16, engine thread counts 1/2/4/8, and — for the long-prompt
//! TTFT story — prefill chunks {1, 8} over 64- and 256-token prompts.
//! Emits reports/BENCH_serve.json (requests/s, p95, and p50/p95
//! prefill/TTFT per configuration; one row per thread count at
//! max_batch 16, one per kernel generation for the ternary engine, and
//! one per (prompt_len, prefill_chunk) point in the long-prompt sweep)
//! and appends the rows to reports/results.jsonl. A final open-loop
//! sweep offers seeded Poisson arrivals at {0.5, 1, 2, 4}x the measured
//! closed-loop capacity with per-request deadlines, producing the
//! saturation / shed-rate / bounded-p99 curves as `kind:"serve_open"`
//! rows in the same files. Outputs are invariant
//! to all three sweeps (the parallel kernels are bitwise identical to
//! serial, the LUT and SIMD kernels to byte-decode, and chunked prefill
//! to token-by-token decode); only throughput/latency/TTFT columns move.
//!
//! Needs no artifacts: falls back to the synthetic tiny spec with random
//! weights (serving speed/memory do not depend on weight values).

// Bench/example crate roots sit outside src/lib.rs, so the Cargo.toml
// clippy deny-list (unwrap_used & co.) is re-allowed here: panicking on
// bad setup is the right behavior for a demo or harness, as in tests.
#![allow(clippy::unwrap_used, clippy::indexing_slicing, clippy::float_cmp)]

use bitnet_distill::bench as harness;
use bitnet_distill::data::{Task, Tokenizer};
use bitnet_distill::engine::KernelKind;

fn main() -> anyhow::Result<()> {
    // first numeric arg = request count; `cargo bench` injects a
    // `--bench` flag into argv even for harness=false targets, so a
    // positional nth(1) would silently miss it
    let n_req: usize = std::env::args()
        .skip(1)
        .find_map(|v| v.parse().ok())
        .unwrap_or(64);
    let (f32e, terne) = harness::serving_engines("tiny", "artifacts")?;
    let mut rows = Vec::new();
    for (name, engine) in [("f32", &f32e), ("ternary", &terne)] {
        let tok = Tokenizer::new(engine.cfg.vocab);
        // the kernel selector only touches ternary matmuls; sweeping it
        // for the f32 engine would just duplicate rows
        let kernels: &[KernelKind] = if name == "ternary" {
            &KernelKind::ALL
        } else {
            &[KernelKind::ByteDecode]
        };
        // classification = prefill-heavy; summarization = decode-heavy
        for (task, n, max_new) in [(Task::Mnli, n_req, 0), (Task::Cnndm, n_req / 4, 16)] {
            let reqs = harness::serve_workload(task, &tok, n.max(1), engine.cfg.seq, max_new, 321);
            for &kernel in kernels {
                let seq = harness::serve_sequential(engine, name, task.name(), &reqs, kernel);
                println!("{}", seq.render());
                rows.push(seq);
                // batching curve at one thread
                for max_batch in [1usize, 4] {
                    let row = harness::serve_batched(
                        engine,
                        name,
                        task.name(),
                        &reqs,
                        max_batch,
                        256,
                        1,
                        kernel,
                        1,
                    );
                    println!("{}", row.render());
                    rows.push(row);
                }
                // thread sweep at full batch: the parallel speedup curve.
                // `threads` is the requested pool size; the pool's work
                // floor caps *effective* workers per matmul by its row
                // count (on the tiny shape only the vocab-size LM head
                // fans wide, so high thread counts converge — expected
                // at this scale).
                for threads in [1usize, 2, 4, 8] {
                    let row = harness::serve_batched(
                        engine,
                        name,
                        task.name(),
                        &reqs,
                        16,
                        256,
                        threads,
                        kernel,
                        1,
                    );
                    println!("{}", row.render());
                    rows.push(row);
                }
            }
        }
    }
    // long-prompt TTFT sweep (ternary engine): pure-prefill workloads
    // at prompt 64/256 tokens, chunked (8) vs unchunked (1) prefill —
    // the rows behind the `prefill_chunk`/TTFT columns of `bitdistill
    // report` and the chunk-speedup trajectory across commits
    for &prompt_len in &[64usize, 256] {
        let prompt_len = prompt_len.min(terne.max_seq());
        // prompt_len lives in the task label: ServeRow has no
        // prompt_len column, and without it the 64- and 256-token rows
        // would collapse into one median in `bitdistill report`
        let label = format!("longprompt{prompt_len}");
        let reqs = harness::long_prompt_workload(
            n_req.clamp(1, 16),
            prompt_len,
            terne.cfg.vocab,
            77,
        );
        for &kernel in &[KernelKind::ByteDecode, KernelKind::Lut, KernelKind::Simd] {
            for &chunk in &[1usize, 8] {
                let row = harness::serve_batched(
                    &terne,
                    "ternary",
                    &label,
                    &reqs,
                    4,
                    256,
                    1,
                    kernel,
                    chunk,
                );
                println!("{}", row.render());
                rows.push(row);
            }
        }
    }
    // open-loop saturation sweep (ternary engine, byte kernel): measure
    // closed-loop capacity once, then offer Poisson arrivals at
    // {0.5, 1, 2, 4}x that rate with a deadline — the shed curve. Below
    // saturation the server completes (nearly) everything; past it,
    // completed req/s flattens at capacity while rejected/expired absorb
    // the excess and completed-request p99 stays deadline-bounded. These
    // land as `kind:"serve_open"` rows next to the closed-loop grid.
    let tok = Tokenizer::new(terne.cfg.vocab);
    let open_reqs =
        harness::serve_workload(Task::Mnli, &tok, n_req.max(16), terne.cfg.seq, 0, 654);
    let cap_cfg = bitnet_distill::serve::ServerCfg {
        max_batch: 8,
        max_queue: 16,
        threads: 1,
        kernel: KernelKind::ByteDecode,
        prefill_chunk: 8,
        metrics_every: 0,
    };
    let cap_row = harness::serve_batched(
        &terne,
        "ternary",
        "mnli",
        &open_reqs,
        cap_cfg.max_batch,
        256,
        cap_cfg.threads,
        cap_cfg.kernel,
        cap_cfg.prefill_chunk,
    );
    let capacity_req_s = cap_row.req_s.max(1.0);
    // deadline ~ a few mean service times at capacity: loose enough that
    // sub-saturation loads complete, tight enough that overload sheds
    let deadline =
        std::time::Duration::from_secs_f64((8.0 / capacity_req_s).clamp(0.05, 2.0));
    let mut open_rows = Vec::new();
    for &mult in &[0.5f64, 1.0, 2.0, 4.0] {
        let row = harness::serve_open_loop(
            &terne,
            "ternary",
            "mnli",
            &open_reqs,
            cap_cfg,
            capacity_req_s * mult,
            mult,
            deadline,
            9000 + (mult * 10.0) as u64,
        );
        println!("{}", row.render());
        open_rows.push(row);
    }
    harness::write_serve_report_full(&rows, &open_rows, "reports/BENCH_serve.json")?;
    harness::append_serve_results(&rows, "reports/results.jsonl")?;
    harness::append_jsonl_rows(
        open_rows.iter().map(harness::OpenLoopRow::to_json).collect(),
        "reports/results.jsonl",
    )?;
    println!(
        "wrote reports/BENCH_serve.json ({} rows)",
        rows.len() + open_rows.len()
    );
    Ok(())
}
