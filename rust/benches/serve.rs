//! Serving throughput: continuous batching vs sequential decode, f32 vs
//! packed-ternary, byte-decode vs activation-LUT kernels, at batch sizes
//! 1/4/16 and engine thread counts 1/2/4/8 — the deployment-scale half
//! of the paper's CPU story. Emits reports/BENCH_serve.json (requests/s
//! and p95 per configuration; one row per thread count at max_batch 16
//! and one per kernel generation for the ternary engine, so both the
//! parallel speedup curve and the LUT-vs-byte-decode curve show up in
//! `bitdistill report`) and appends the rows to reports/results.jsonl.
//! Outputs are invariant to both sweeps (the parallel kernels are
//! bitwise identical to serial, and the LUT kernels to byte-decode);
//! only the throughput and latency columns move.
//!
//! Needs no artifacts: falls back to the synthetic tiny spec with random
//! weights (serving speed/memory do not depend on weight values).

use bitnet_distill::bench as harness;
use bitnet_distill::data::{Task, Tokenizer};
use bitnet_distill::engine::KernelKind;

fn main() -> anyhow::Result<()> {
    // first numeric arg = request count; `cargo bench` injects a
    // `--bench` flag into argv even for harness=false targets, so a
    // positional nth(1) would silently miss it
    let n_req: usize = std::env::args()
        .skip(1)
        .find_map(|v| v.parse().ok())
        .unwrap_or(64);
    let (f32e, terne) = harness::serving_engines("tiny", "artifacts")?;
    let mut rows = Vec::new();
    for (name, engine) in [("f32", &f32e), ("ternary", &terne)] {
        let tok = Tokenizer::new(engine.cfg.vocab);
        // the kernel selector only touches ternary matmuls; sweeping it
        // for the f32 engine would just duplicate rows
        let kernels: &[KernelKind] = if name == "ternary" {
            &[KernelKind::ByteDecode, KernelKind::Lut]
        } else {
            &[KernelKind::ByteDecode]
        };
        // classification = prefill-heavy; summarization = decode-heavy
        for (task, n, max_new) in [(Task::Mnli, n_req, 0), (Task::Cnndm, n_req / 4, 16)] {
            let reqs = harness::serve_workload(task, &tok, n.max(1), engine.cfg.seq, max_new, 321);
            for &kernel in kernels {
                let seq = harness::serve_sequential(engine, name, task, &reqs, kernel);
                println!("{}", seq.render());
                rows.push(seq);
                // batching curve at one thread
                for max_batch in [1usize, 4] {
                    let row = harness::serve_batched(
                        engine,
                        name,
                        task,
                        &reqs,
                        max_batch,
                        256,
                        1,
                        kernel,
                    );
                    println!("{}", row.render());
                    rows.push(row);
                }
                // thread sweep at full batch: the parallel speedup curve.
                // `threads` is the requested pool size; the pool's work
                // floor caps *effective* workers per matmul by its row
                // count (on the tiny shape only the vocab-size LM head
                // fans wide, so high thread counts converge — expected
                // at this scale).
                for threads in [1usize, 2, 4, 8] {
                    let row = harness::serve_batched(
                        engine,
                        name,
                        task,
                        &reqs,
                        16,
                        256,
                        threads,
                        kernel,
                    );
                    println!("{}", row.render());
                    rows.push(row);
                }
            }
        }
    }
    harness::write_serve_report(&rows, "reports/BENCH_serve.json")?;
    harness::append_serve_results(&rows, "reports/results.jsonl")?;
    println!("wrote reports/BENCH_serve.json ({} rows)", rows.len());
    Ok(())
}
