//! Classification scenario: evaluate a trained checkpoint through every
//! serving path — the HLO QAT forward, the f32 rust engine, and the
//! packed-ternary rust engine — demonstrating that the deployment engine
//! preserves task accuracy (the claim behind Tables 1/3/4).
//!
//!   cargo run --release --example classification -- [ckpt] [task]
//!
//! Without arguments it quick-trains a BitDistill student on the MNLI
//! analog (scaled budget) and evaluates that.

// Bench/example crate roots sit outside src/lib.rs, so the Cargo.toml
// clippy deny-list (unwrap_used & co.) is re-allowed here: panicking on
// bad setup is the right behavior for a demo or harness, as in tests.
#![allow(clippy::unwrap_used, clippy::indexing_slicing, clippy::float_cmp)]

use bitnet_distill::bench;
use bitnet_distill::data::Task;
use bitnet_distill::engine::Engine;
use bitnet_distill::params::ParamStore;
use bitnet_distill::pipeline::{self, Ctx, StudentOpts};
use bitnet_distill::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let rt = Runtime::open("artifacts")?;
    let mut ctx = Ctx::new(&rt, "runs/quickstart");
    let task = args
        .get(1)
        .and_then(|t| Task::parse(t))
        .unwrap_or(Task::Mnli);

    let ckpt = match args.first() {
        Some(p) => std::path::PathBuf::from(p),
        None => {
            ctx.steps_scale = 0.15;
            println!("no checkpoint given: quick-training BitDistill on {}", task.name());
            let opts = StudentOpts::defaults_for(task, 4);
            pipeline::bitdistill(&ctx, "tiny", task, &opts, true)?.ckpt
        }
    };

    let params = ParamStore::load(&ckpt)?;
    let spec = rt.manifest.model(&params.model_key)?;
    println!("model: {} ({} params)", params.model_key, params.n_params());
    let ds = pipeline::eval_set(&ctx, task, 192);

    // 1. HLO QAT forward (training-time semantics)
    let fwd = bench::fwd_artifact_for(&rt, &params.model_key)?;
    let acc_hlo = pipeline::eval_classification(&rt, &fwd, &params, &ds, &ctx.tok, task)?;
    println!("accuracy via HLO {fwd}: {acc_hlo:.2}");

    // 2. rust engine, f32 weights (master-weight deployment)
    let e32 = Engine::from_params(spec, &params, false)?;
    let acc_f32 = pipeline::eval_classification_engine(&e32, &ds, &ctx.tok, task);
    println!("accuracy via rust engine f32: {acc_f32:.2}");

    // 3. rust engine, packed ternary (the 1.58-bit deployment)
    let et = Engine::from_params(spec, &params, true)?;
    let acc_t = pipeline::eval_classification_engine(&et, &ds, &ctx.tok, task);
    println!(
        "accuracy via rust engine ternary: {acc_t:.2}  (weights {:.2} MB vs {:.2} MB f32)",
        et.weight_bytes() as f64 / 1e6,
        e32.weight_bytes() as f64 / 1e6
    );
    if params.model_key.contains("absmean") {
        assert!(
            (acc_hlo - acc_t).abs() < 6.0,
            "ternary deployment lost accuracy: {acc_hlo:.2} vs {acc_t:.2}"
        );
    }
    Ok(())
}
