// Diagnostic: RSS growth per train step — execute (literals) vs
// execute_b (explicit device buffers). See EXPERIMENTS.md §Perf.
// Bench/example crate roots sit outside src/lib.rs, so the Cargo.toml
// clippy deny-list (unwrap_used & co.) is re-allowed here: panicking on
// bad setup is the right behavior for a demo or harness, as in tests.
#![allow(clippy::unwrap_used, clippy::indexing_slicing, clippy::float_cmp)]

use bitnet_distill::data::{CorpusBatcher, CorpusStream, Tokenizer};
use bitnet_distill::params::ParamStore;
use bitnet_distill::pipeline::Trainer;
use bitnet_distill::runtime::Runtime;
use bitnet_distill::substrate::Rng;

fn rss_mb() -> f64 {
    let s = std::fs::read_to_string("/proc/self/status").unwrap();
    for l in s.lines() {
        if l.starts_with("VmRSS") {
            let kb: f64 = l.split_whitespace().nth(1).unwrap().parse().unwrap();
            return kb / 1024.0;
        }
    }
    0.0
}

fn main() -> anyhow::Result<()> {
    let mode = std::env::args().nth(1).unwrap_or_else(|| "literal".into());
    let rt = Runtime::open("artifacts")?;
    let tok = Tokenizer::new(rt.manifest.vocab);
    let spec = rt.manifest.model("tiny-nosubln-none")?;
    let mut rng = Rng::new(1);
    let params = ParamStore::init(spec, &mut rng);
    let mut tr = Trainer::new(&rt, "tiny_lm_train", params);
    tr.use_buffers = mode == "buffers";
    let stream = CorpusStream::new(&tok, rt.manifest.seq, 3);
    let mut batches = CorpusBatcher::new(stream, rt.manifest.batch, rt.manifest.seq);
    println!("mode={mode} rss0={:.0}MB", rss_mb());
    for s in 0..40 {
        let b = batches.next_batch();
        tr.train_step(&b, 1e-3)?;
        if s % 10 == 9 {
            println!("step {} rss={:.0}MB", s + 1, rss_mb());
        }
    }
    Ok(())
}
