//! Summarization scenario (the CNNDM analog, Table 2): distill a 1.58-bit
//! student, then *generate* summaries through the packed-ternary engine
//! with greedy decoding and a KV cache, scoring BLEU / ROUGE.
//!
//!   cargo run --release --example summarization -- [ckpt]

// Bench/example crate roots sit outside src/lib.rs, so the Cargo.toml
// clippy deny-list (unwrap_used & co.) is re-allowed here: panicking on
// bad setup is the right behavior for a demo or harness, as in tests.
#![allow(clippy::unwrap_used, clippy::indexing_slicing, clippy::float_cmp)]

use bitnet_distill::data::{tokenizer::EOS, Task};
use bitnet_distill::engine::Engine;
use bitnet_distill::params::ParamStore;
use bitnet_distill::pipeline::{self, Ctx, StudentOpts};
use bitnet_distill::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let rt = Runtime::open("artifacts")?;
    let mut ctx = Ctx::new(&rt, "runs/quickstart");

    let ckpt = match args.first() {
        Some(p) => std::path::PathBuf::from(p),
        None => {
            ctx.steps_scale = 0.15;
            println!("no checkpoint given: quick-training BitDistill on cnndm");
            let opts = StudentOpts::defaults_for(Task::Cnndm, 4);
            pipeline::bitdistill(&ctx, "tiny", Task::Cnndm, &opts, true)?.ckpt
        }
    };

    let params = ParamStore::load(&ckpt)?;
    let spec = rt.manifest.model(&params.model_key)?;
    let ternary = spec.config.quant_method != "none";
    let engine = Engine::from_params(spec, &params, ternary)?;
    println!(
        "engine: {} ({}, {:.2} MB weights)",
        params.model_key,
        if ternary { "packed ternary" } else { "f32" },
        engine.weight_bytes() as f64 / 1e6
    );

    let ds = pipeline::eval_set(&ctx, Task::Cnndm, 48);

    // show three sample generations
    for ex in ds.iter().take(3) {
        let hyp = engine.generate(&ex.tokens[..ex.prompt_len], 24, EOS);
        println!("\narticle : {}", ctx.tok.decode_all(&ex.tokens[..ex.prompt_len.min(48)]));
        println!("reference: {}", ctx.tok.decode(&ex.reference).join(" "));
        println!("generated: {}", ctx.tok.decode(&hyp).join(" "));
    }

    let m = pipeline::eval_summarization(&engine, &ds, &ctx.tok, 24);
    println!(
        "\ncorpus metrics (n={}): BLEU={:.2} ROUGE-1={:.2} ROUGE-2={:.2} \
         ROUGE-L={:.2} ROUGE-Lsum={:.2} AVG={:.2}",
        ds.len(),
        m.bleu,
        m.rouge1,
        m.rouge2,
        m.rouge_l,
        m.rouge_lsum,
        m.avg()
    );
    Ok(())
}
