//! Quickstart: the full BitDistill pipeline end-to-end on a scaled-down
//! budget (~2 minutes on one CPU core).
//!
//!   cargo run --release --example quickstart
//!
//! What happens (paper §3):
//!   0. pretrain a tiny full-precision base LM on the TinyWorld corpus
//!      (stands in for the off-the-shelf pretrained LLM),
//!   1. Stage-1: re-shape it into a SubLN student,
//!   2. Stage-2: continual pre-training of the 1.58-bit student,
//!   3. FP16-SFT the teacher on the SST-2 analog,
//!   4. Stage-3: CE + logits-KD + attention-relation-KD distillation,
//!   5. evaluate FP16-SFT vs BitNet-SFT vs BitDistill, and show the
//!      ternary engine's speed/memory edge.
//!
//! For the paper-scale runs use the CLI: `bitdistill bench --exp table1`.

// Bench/example crate roots sit outside src/lib.rs, so the Cargo.toml
// clippy deny-list (unwrap_used & co.) is re-allowed here: panicking on
// bad setup is the right behavior for a demo or harness, as in tests.
#![allow(clippy::unwrap_used, clippy::indexing_slicing, clippy::float_cmp)]

use bitnet_distill::bench;
use bitnet_distill::data::Task;
use bitnet_distill::pipeline::{self, Ctx, StudentOpts};
use bitnet_distill::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open("artifacts")?;
    println!("PJRT platform: {}", rt.platform());
    let mut ctx = Ctx::new(&rt, "runs/quickstart");
    ctx.steps_scale = 0.08; // ~40 pretrain steps, ~15 per stage

    let task = Task::Sst2;
    let opts = StudentOpts::defaults_for(task, 4);

    println!("\n== FP16-SFT (teacher) ==");
    let teacher = pipeline::teacher_sft(&ctx, "tiny", task)?;
    let s = bench::evaluate_ckpt(&ctx, &teacher, task, "tiny", "fp16-sft", &opts)?;
    println!("{}", s.render());

    println!("\n== BitNet-SFT (direct QAT baseline) ==");
    let bitnet = pipeline::bitnet_sft(&ctx, "tiny", task, &opts, false)?;
    let s = bench::evaluate_ckpt(&ctx, &bitnet, task, "tiny", "bitnet-sft", &opts)?;
    println!("{}", s.render());

    println!("\n== BitDistill (3-stage pipeline) ==");
    let trace = pipeline::bitdistill(&ctx, "tiny", task, &opts, true)?;
    let s = bench::evaluate_ckpt(&ctx, &trace.ckpt, task, "tiny", "bitdistill", &opts)?;
    println!("{}", s.render());

    println!("\n== deployment: ternary engine vs f32 ==");
    println!(
        "{}",
        bench::speed_report(&rt, "tiny", 256, bitnet_distill::engine::KernelKind::ByteDecode)?
    );
    println!(
        "\nNote: at steps_scale={} these accuracies are far from converged —\n\
         run `bitdistill bench --exp table1` for the paper-scale numbers.",
        ctx.steps_scale
    );
    Ok(())
}
