// Dump task datasets to JSON for cross-layer debugging.
// Bench/example crate roots sit outside src/lib.rs, so the Cargo.toml
// clippy deny-list (unwrap_used & co.) is re-allowed here: panicking on
// bad setup is the right behavior for a demo or harness, as in tests.
#![allow(clippy::unwrap_used, clippy::indexing_slicing, clippy::float_cmp)]

use bitnet_distill::data::{Task, TaskGen, Tokenizer};
use bitnet_distill::substrate::json::{self, Json};
fn task_seed(name: &str, salt: u64) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in name.bytes() { h ^= b as u64; h = h.wrapping_mul(0x100000001b3); }
    h ^ salt
}
fn main() {
    let tok = Tokenizer::new(1024);
    let task = Task::Sst2;
    let gen = TaskGen::new(task, &tok, 128);
    let mut arr = Vec::new();
    for (salt, n) in [(1u64, 768usize), (2, 128)] {
        for ex in gen.dataset(n, task_seed(task.name(), salt)) {
            arr.push(json::obj(vec![
                ("tokens", Json::Arr(ex.tokens.iter().map(|&t| json::num(t as f64)).collect())),
                ("labels", Json::Arr(ex.labels.iter().map(|&t| json::num(t as f64)).collect())),
                ("class", json::num(ex.class as f64)),
                ("prompt_len", json::num(ex.prompt_len as f64)),
                ("split", json::num(if salt == 1 {0.0} else {1.0})),
            ]));
        }
    }
    std::fs::write("/tmp/sst2.json", Json::Arr(arr).to_string()).unwrap();
    eprintln!("wrote /tmp/sst2.json");
}
