//! CPU serving demo: batch of classification requests served through the
//! packed-ternary engine, reporting latency percentiles, throughput and
//! the memory footprint — the deployment story behind Fig. 1's right
//! panels (2.65x CPU speedup, 10x memory).
//!
//!   cargo run --release --example serve_cpu -- [n_requests]

use std::time::Instant;

use bitnet_distill::data::{Task, TaskGen, Tokenizer};
use bitnet_distill::engine::Engine;
use bitnet_distill::params::ParamStore;
use bitnet_distill::pipeline::stages;
use bitnet_distill::runtime::Runtime;
use bitnet_distill::substrate::Rng;

fn main() -> anyhow::Result<()> {
    let n_req: usize = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(64);
    let rt = Runtime::open("artifacts")?;
    let tok = Tokenizer::new(rt.manifest.vocab);

    // use the trained student if one exists, else random weights (serving
    // performance does not depend on weight values)
    let skey = stages::model_key("tiny", true, "absmean");
    let spec = rt.manifest.model(&skey)?;
    let params = ["runs/bitdistill_tiny_mnli_dl2.ckpt", "runs/quickstart/bitdistill_tiny_mnli_dl2.ckpt"]
        .iter()
        .find(|p| std::path::Path::new(p).exists())
        .map(ParamStore::load)
        .transpose()?
        .unwrap_or_else(|| {
            let mut rng = Rng::new(1);
            ParamStore::init(spec, &mut rng)
        });

    for (name, ternary) in [("f32", false), ("ternary-1.58bit", true)] {
        let engine = Engine::from_params(spec, &params, ternary)?;
        let gen = TaskGen::new(Task::Mnli, &tok, rt.manifest.seq);
        let requests = gen.dataset(n_req, 321);

        let mut cache = engine.new_cache();
        let mut scratch = engine.new_scratch();
        let mut lat_ms: Vec<f64> = Vec::with_capacity(n_req);
        let mut total_toks = 0usize;
        let t0 = Instant::now();
        for req in &requests {
            let t1 = Instant::now();
            cache.reset();
            for &t in &req.tokens[..req.prompt_len] {
                engine.decode_step(t, &mut cache, &mut scratch);
            }
            total_toks += req.prompt_len;
            lat_ms.push(t1.elapsed().as_secs_f64() * 1e3);
        }
        let wall = t0.elapsed().as_secs_f64();
        lat_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p = |q: f64| lat_ms[((lat_ms.len() as f64 * q) as usize).min(lat_ms.len() - 1)];
        println!(
            "{name:16} {n_req} reqs: {:.1} tok/s, {:.1} req/s, \
             p50={:.1}ms p95={:.1}ms p99={:.1}ms, weights={:.2}MB kv={:.2}MB",
            total_toks as f64 / wall,
            n_req as f64 / wall,
            p(0.5),
            p(0.95),
            p(0.99),
            engine.weight_bytes() as f64 / 1e6,
            cache.memory_bytes() as f64 / 1e6,
        );
    }
    Ok(())
}
