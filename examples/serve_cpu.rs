//! Continuous-batching CPU serving demo over the packed-ternary engine —
//! the deployment story behind Fig. 1's right panels (10x weight memory,
//! faster CPU decode), now at server shape: a queue of classification
//! requests is admitted into a dynamic batch (join on arrival, retire on
//! finish) and stepped through `Engine::decode_step_batch`, versus the
//! old one-request-at-a-time loop as the baseline.
//!
//!   cargo run --release --example serve_cpu -- [n_requests] [max_batch] [threads]
//!
//! Works without artifacts: falls back to the synthetic tiny spec with
//! random weights (serving speed/memory do not depend on weight values).

// Bench/example crate roots sit outside src/lib.rs, so the Cargo.toml
// clippy deny-list (unwrap_used & co.) is re-allowed here: panicking on
// bad setup is the right behavior for a demo or harness, as in tests.
#![allow(clippy::unwrap_used, clippy::indexing_slicing, clippy::float_cmp)]

use bitnet_distill::bench as harness;
use bitnet_distill::data::{Task, Tokenizer};
use bitnet_distill::engine::KernelKind;
use bitnet_distill::serve::{quantile_unsorted, Request, Server, ServerCfg};

fn main() -> anyhow::Result<()> {
    let n_req: usize = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(64);
    let max_batch: usize = std::env::args()
        .nth(2)
        .and_then(|v| v.parse().ok())
        .unwrap_or(16);
    // engine worker threads: outputs are identical at every count (the
    // parallel kernels are bitwise-equal to serial); only speed moves
    let threads: usize = std::env::args()
        .nth(3)
        .and_then(|v| v.parse().ok())
        .unwrap_or(1);

    let (f32e, terne) = harness::serving_engines("tiny", "artifacts")?;
    for (name, engine) in [("f32", &f32e), ("ternary-1.58bit", &terne)] {
        let tok = Tokenizer::new(engine.cfg.vocab);
        let reqs: Vec<Request> =
            harness::serve_workload(Task::Mnli, &tok, n_req, engine.cfg.seq, 0, 321);

        // baseline: the pre-serve sequential loop (one cache, reset per
        // request)
        let seq = harness::serve_sequential(
            engine,
            name,
            Task::Mnli.name(),
            &reqs,
            KernelKind::ByteDecode,
        );

        // continuous batching through the server
        let mut srv = Server::new(
            engine,
            ServerCfg { max_batch, max_queue: n_req.max(1), threads, ..ServerCfg::default() },
        );
        let t0 = std::time::Instant::now();
        for r in &reqs {
            srv.submit(r.clone());
        }
        let responses = srv.run_to_completion();
        let wall = t0.elapsed().as_secs_f64();

        let lat: Vec<f64> = responses.iter().map(|r| r.timing.total_ms).collect();
        let queue: Vec<f64> = responses.iter().map(|r| r.timing.queue_ms).collect();
        let tok_s =
            (srv.stats.prompt_tokens + srv.stats.new_tokens) as f64 / wall.max(1e-9);
        println!(
            "{name:16} seq : {:6.1} tok/s  p50={:.1}ms p95={:.1}ms p99={:.1}ms",
            seq.tok_s, seq.p50_ms, seq.p95_ms, seq.p99_ms
        );
        println!(
            "{name:16} b={max_batch:<3} t={threads}: {tok_s:6.1} tok/s  p50={:.1}ms p95={:.1}ms \
             p99={:.1}ms queue_p95={:.1}ms occupancy={:.2}  ({:.2}x vs seq)",
            quantile_unsorted(&lat, 0.50),
            quantile_unsorted(&lat, 0.95),
            quantile_unsorted(&lat, 0.99),
            quantile_unsorted(&queue, 0.95),
            srv.stats.mean_occupancy(),
            tok_s / seq.tok_s.max(1e-9),
        );
        println!(
            "{name:16} weights={:.2}MB kv_pool={:.2}MB requests={} completed={}",
            engine.weight_bytes() as f64 / 1e6,
            srv.kv_memory_bytes() as f64 / 1e6,
            n_req,
            srv.stats.completed,
        );
    }
    Ok(())
}
