//! Minimal std-only TCP client for the `bitdistill serve --listen`
//! front-end — the wire protocol demo and the CI net-smoke driver.
//!
//!   cargo run --release --example net_client -- ADDR \
//!       [--requests N] [--misbehave] [--shutdown]
//!
//! The protocol is newline-delimited JSON both ways (see
//! src/README.md, "network front-end"): the client writes one request
//! object per line, the server streams `{"frame":"token",...}` lines as
//! tokens are generated and finishes each request with one terminal
//! `done` / `reject` / `canceled` frame (plus a `timing` frame for
//! served requests).
//!
//! - Default: connects (with retry, so a freshly spawned server can
//!   finish binding), sends `--requests N` (default 4) generate and
//!   classify requests, and prints each terminal frame.
//! - `--misbehave`: additionally (1) sends one malformed frame and one
//!   unseeded-sampling frame and expects typed `reject` frames back —
//!   the connection must survive both — and (2) opens a second
//!   connection, bursts long-running generates, and drops it
//!   mid-stream without reading, exercising cancel-on-disconnect
//!   (watch `canceled` in the server's metrics/stats output).
//! - `--shutdown`: finally sends `{"op":"shutdown"}` so the server
//!   drains and exits — this is how CI ends the smoke test cleanly.

// Bench/example crate roots sit outside src/lib.rs, so the Cargo.toml
// clippy deny-list (unwrap_used & co.) is re-allowed here: panicking on
// bad setup is the right behavior for a demo or harness, as in tests.
#![allow(clippy::unwrap_used, clippy::indexing_slicing, clippy::float_cmp)]

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use bitnet_distill::substrate::Json;

/// Connect with retry: the smoke test spawns the server concurrently,
/// so the listener may not be up on the first attempt.
fn connect(addr: &str) -> Result<TcpStream> {
    let mut last = None;
    for _ in 0..40 {
        match TcpStream::connect(addr) {
            Ok(s) => {
                s.set_read_timeout(Some(Duration::from_secs(10)))?;
                s.set_write_timeout(Some(Duration::from_secs(10)))?;
                return Ok(s);
            }
            Err(e) => last = Some(e),
        }
        std::thread::sleep(Duration::from_millis(250));
    }
    Err(anyhow!("could not connect to {addr}: {last:?}"))
}

fn send_line(stream: &mut TcpStream, line: &str) -> Result<()> {
    stream.write_all(line.as_bytes())?;
    stream.write_all(b"\n")?;
    Ok(())
}

/// Read frames until a terminal one (`done`/`reject`/`canceled`)
/// arrives; returns it. Token and timing frames are counted, not kept.
fn read_terminal(reader: &mut BufReader<TcpStream>) -> Result<Json> {
    let mut tokens = 0usize;
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            bail!("server closed the connection before a terminal frame");
        }
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line.trim()).map_err(|e| anyhow!("bad frame {line:?}: {e}"))?;
        match j.get("frame").and_then(Json::as_str) {
            Some("token") => tokens += 1,
            Some("timing") => {}
            Some("done") | Some("reject") | Some("canceled") => {
                if tokens > 0 {
                    println!("  ({tokens} streamed token frames)");
                }
                return Ok(j);
            }
            other => bail!("unexpected frame kind {other:?} in {line:?}"),
        }
    }
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let addr = args
        .first()
        .cloned()
        .ok_or_else(|| anyhow!("usage: net_client ADDR [--requests N] [--misbehave] [--shutdown]"))?;
    let n_req: usize = args
        .iter()
        .position(|a| a == "--requests")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(4);
    let misbehave = args.iter().any(|a| a == "--misbehave");
    let shutdown = args.iter().any(|a| a == "--shutdown");

    // --- well-behaved traffic: alternating generate / classify ---
    let stream = connect(&addr)?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    for i in 0..n_req {
        let line = if i % 2 == 0 {
            format!(r#"{{"op":"generate","prompt":[{},4,6],"max_new":8}}"#, 1 + i % 3)
        } else {
            format!(r#"{{"op":"classify","prompt":[2,{},5],"labels":[7,8,9]}}"#, 1 + i % 4)
        };
        send_line(&mut writer, &line)?;
        let t = read_terminal(&mut reader)?;
        println!("request {i}: {}", t.to_string());
        if t.get("frame").and_then(Json::as_str) != Some("done") {
            bail!("expected a done frame for well-formed request {i}, got {}", t.to_string());
        }
    }
    drop(writer);
    drop(reader);

    if misbehave {
        // --- malformed traffic: the connection must answer with typed
        // rejects and stay alive for a valid request afterwards ---
        let stream = connect(&addr)?;
        let mut writer = stream.try_clone()?;
        let mut reader = BufReader::new(stream);
        send_line(&mut writer, "this is not json")?;
        let r1 = read_terminal(&mut reader)?;
        println!("malformed frame -> {}", r1.to_string());
        send_line(
            &mut writer,
            r#"{"op":"generate","prompt":[1,2],"max_new":4,"sampling":{"kind":"temperature","temp":0.8}}"#,
        )?;
        let r2 = read_terminal(&mut reader)?;
        println!("unseeded sampling -> {}", r2.to_string());
        for (name, r) in [("malformed", &r1), ("unseeded", &r2)] {
            if r.get("frame").and_then(Json::as_str) != Some("reject") {
                bail!("expected a reject frame for the {name} request, got {}", r.to_string());
            }
        }
        send_line(&mut writer, r#"{"op":"generate","prompt":[3,1],"max_new":4}"#)?;
        let r3 = read_terminal(&mut reader)?;
        println!("valid after rejects -> {}", r3.to_string());
        if r3.get("frame").and_then(Json::as_str) != Some("done") {
            bail!("connection should survive rejects and still serve, got {}", r3.to_string());
        }
        drop(writer);
        drop(reader);

        // --- mid-stream disconnect: burst long-running generates and
        // drop the socket without reading a byte. The unread response
        // data forces an abortive close, the server's reader sees the
        // error, and every outstanding request is canceled
        // (FinishReason::Canceled frees the KV slots mid-flight).
        let mut burst = connect(&addr)?;
        for _ in 0..16 {
            send_line(&mut burst, r#"{"op":"generate","prompt":[1,2,3],"max_new":100000,"eos":-1}"#)?;
        }
        drop(burst);
        println!("mid-stream disconnect sent (server should report canceled requests)");
    }

    if shutdown {
        let mut s = connect(&addr)?;
        send_line(&mut s, r#"{"op":"shutdown"}"#)?;
        println!("shutdown sent");
    }
    Ok(())
}
