# pytest: Stage-3 losses — CE masking, KD limits, Algorithm-1 transcription.
import jax
import jax.numpy as jnp
import numpy as np

from compile.losses import (IGNORE, attention_relation_loss, ce_loss,
                            logits_kd_loss, _relation_logprobs)


def _logits(seed, shape=(2, 8, 32)):
    return jax.random.normal(jax.random.PRNGKey(seed), shape)


def test_ce_ignores_masked_positions():
    logits = _logits(0)
    labels = jnp.full((2, 8), IGNORE, jnp.int32)
    labels = labels.at[0, 3].set(7)
    l1 = ce_loss(logits, labels)
    # perturb every masked position's logits -> loss unchanged
    pert = logits.at[:, 5:].add(100.0)
    pert = pert.at[0, 3].set(logits[0, 3])
    l2 = ce_loss(pert, labels)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_ce_perfect_prediction_is_zero():
    labels = jnp.array([[1, 2], [3, IGNORE]], jnp.int32)
    logits = jax.nn.one_hot(jnp.maximum(labels, 0), 8) * 100.0
    assert float(ce_loss(logits, labels)) < 1e-4


def test_ce_uniform_is_log_vocab():
    labels = jnp.zeros((2, 4), jnp.int32)
    logits = jnp.zeros((2, 4, 32))
    np.testing.assert_allclose(float(ce_loss(logits, labels)),
                               np.log(32), rtol=1e-5)


def test_kd_zero_when_identical():
    logits = _logits(1)
    labels = jnp.zeros((2, 8), jnp.int32)
    assert abs(float(logits_kd_loss(logits, logits, labels, 5.0))) < 1e-6


def test_kd_positive_and_temperature_softens():
    t, s = _logits(2), _logits(3)
    labels = jnp.zeros((2, 8), jnp.int32)
    k1 = float(logits_kd_loss(t, s, labels, 1.0))
    k5 = float(logits_kd_loss(t, s, labels, 5.0))
    assert k1 > 0 and k5 > 0
    assert k5 < k1  # higher tau -> softer distributions -> smaller KL


def test_ad_zero_for_identical_states():
    states = jax.random.normal(jax.random.PRNGKey(4), (3, 2, 4, 8, 16))
    assert abs(float(attention_relation_loss(states, states, 4))) < 1e-6


def test_ad_positive_for_different_states():
    t = jax.random.normal(jax.random.PRNGKey(5), (3, 2, 4, 8, 16))
    s = jax.random.normal(jax.random.PRNGKey(6), (3, 2, 4, 8, 16))
    # random states give near-uniform relation rows, so the KL is small but
    # must be strictly positive
    assert float(attention_relation_loss(t, s, 4)) > 1e-3


def test_ad_cross_width_teacher():
    """Fig. 3c: teacher with more/wider heads still yields TxT relations."""
    t = jax.random.normal(jax.random.PRNGKey(7), (3, 2, 8, 8, 32))
    s = jax.random.normal(jax.random.PRNGKey(8), (3, 2, 4, 8, 16))
    v = float(attention_relation_loss(t, s, 4))
    assert np.isfinite(v) and v > 0


def test_ad_matches_algorithm1_transcription():
    """Direct numpy transcription of the paper's Algorithm 1 (with
    temperature = sqrt(D)) agrees with our jax implementation."""
    B, H, T, hd, split = 2, 4, 6, 8, 4
    rng = np.random.RandomState(0)
    t_states = rng.randn(3, B, H, T, hd).astype(np.float32)
    s_states = rng.randn(3, B, H, T, hd).astype(np.float32)
    D = H * hd // split
    total = 0.0
    for i in range(3):
        def rel(v):
            v = v.transpose(0, 2, 1, 3).reshape(B, T, split, D)
            v = v.transpose(0, 2, 1, 3)
            v = v / np.maximum(
                np.linalg.norm(v, axis=-1, keepdims=True), 1e-8)
            r = (v @ v.transpose(0, 1, 3, 2)) / np.sqrt(D)
            r = r - r.max(-1, keepdims=True)
            e = np.exp(r)
            p = e / e.sum(-1, keepdims=True)
            return p
        tp, sp = rel(t_states[i]), rel(s_states[i])
        kl = (tp * (np.log(tp) - np.log(sp))).sum(-1)  # [B, split, T]
        total += kl.sum() / (B * split * T)
    got = float(attention_relation_loss(jnp.array(t_states),
                                        jnp.array(s_states), split))
    np.testing.assert_allclose(got, total, rtol=1e-4)


def test_relation_rows_are_distributions():
    states = jax.random.normal(jax.random.PRNGKey(9), (2, 4, 8, 16))
    lp = _relation_logprobs(states, 4)
    rows = np.exp(np.asarray(lp)).sum(-1)
    np.testing.assert_allclose(rows, 1.0, rtol=1e-5)
