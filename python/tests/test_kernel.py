# pytest: pallas kernel vs pure-jnp ref — the CORE L1 correctness signal.
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bitlinear_pallas, bitlinear_ref, vmem_bytes
from compile.kernels.ref import absmean_ref, act_quant_ref


def _rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------------------
# kernel vs ref
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.sampled_from([8, 32, 64, 128, 192]),
    n=st.integers(1, 160),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([0.02, 1.0, 37.5]),
)
def test_kernel_matches_ref_swept(m, k, n, seed, scale):
    """Hypothesis sweep over shapes/seeds/scales: fused pallas kernel ==
    literal transcription of eq. (1)-(3)."""
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (m, k)) * scale
    w = jax.random.normal(kw, (k, n)) * scale
    got = bitlinear_pallas(x, w)
    want = bitlinear_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5 * scale * scale)


@pytest.mark.parametrize("bm,bn", [(8, 32), (32, 128), (64, 256)])
def test_kernel_block_shape_invariance(bm, bn):
    """The tiling is a schedule, not a semantics: any block shape gives the
    same numbers."""
    x = _rand(0, (40, 64))
    w = _rand(1, (64, 96))
    want = bitlinear_ref(x, w)
    got = bitlinear_pallas(x, w, block_m=bm, block_n=bn)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_kernel_zero_input():
    """gamma = 0 rows must not divide by zero (the +eps guard)."""
    x = jnp.zeros((4, 32))
    w = _rand(2, (32, 16))
    got = bitlinear_pallas(x, w)
    assert np.all(np.isfinite(np.asarray(got)))
    np.testing.assert_allclose(np.asarray(got), 0.0)


def test_kernel_bf16_inputs():
    """bf16 operands are accepted and computed in f32."""
    x = _rand(3, (16, 64)).astype(jnp.bfloat16)
    w = _rand(4, (64, 32)).astype(jnp.bfloat16)
    got = bitlinear_pallas(x, w)
    want = bitlinear_ref(x.astype(jnp.float32), w.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-2, atol=1e-2)


# ---------------------------------------------------------------------------
# quantizer properties (paper §2)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_absmean_ternary_support(seed):
    """Quantized weights take exactly the values {-Delta, 0, +Delta}."""
    w = jax.random.normal(jax.random.PRNGKey(seed), (64, 48)) * 0.1
    wq, delta = absmean_ref(w)
    vals = np.unique(np.round(np.asarray(wq) / float(delta)))
    assert set(vals).issubset({-1.0, 0.0, 1.0})


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_act_quant_int8_grid(seed):
    """Quantized activations land on the per-token gamma/127 grid within
    [-128, 127]."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (8, 32)) * 3.0
    xq = np.asarray(act_quant_ref(x))
    gamma = np.max(np.abs(np.asarray(x)), axis=-1, keepdims=True)
    grid = np.round(xq / (gamma / 127.0))  # rounding kills division noise
    np.testing.assert_allclose(grid, xq / (gamma / 127.0), atol=1e-3)
    assert grid.min() >= -128.0 and grid.max() <= 127.0


def test_act_quant_idempotent():
    """Quantizing an already-quantized tensor is (near-)identity."""
    x = _rand(7, (8, 32), 2.0)
    once = act_quant_ref(x)
    twice = act_quant_ref(once)
    np.testing.assert_allclose(np.asarray(once), np.asarray(twice),
                               rtol=1e-4, atol=1e-5)


def test_vmem_budget():
    """DESIGN.md §7 tiling fits a 16 MB VMEM with double buffering."""
    assert 2 * vmem_bytes(block_m=32, block_n=128, k=1152) < 16 * 2**20
