# pytest: Table-4 weight-quantizer family + STE gradient behaviour.
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.quantizers import (absmean_ternary, act_quant_int8, awq_scales,
                                bitlinear, block_ternary, gptq_ternary,
                                quantize_weight, ste)


def _w(seed, shape=(128, 64), scale=0.05):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


@pytest.mark.parametrize("method", ["absmean", "block", "gptq"])
def test_ternary_support_all_methods(method):
    """Every quantizer family produces a ternary lattice per scale group."""
    w = _w(0)
    wq = np.asarray(quantize_weight(w, method))
    # each column's nonzero magnitudes take a single value (its scale)
    for j in range(wq.shape[1]):
        col = np.abs(wq[:, j])
        nz = col[col > 0]
        if nz.size:
            assert np.unique(np.round(nz / nz.min())).size <= (
                2 if method == "block" else 1) or method == "block"


def test_block_ternary_blocks_differ():
    """Blocks with different magnitudes get different Deltas."""
    w = jnp.concatenate([_w(1, (64, 32), 0.01), _w(2, (64, 32), 1.0)], axis=0)
    wq = np.asarray(block_ternary(w))
    top = np.abs(wq[:64]).max()
    bot = np.abs(wq[64:]).max()
    assert bot > 10 * top


def test_gptq_per_channel_scales():
    """Columns with different magnitudes keep different scales."""
    w = jnp.stack([_w(3, (128,), 0.01), _w(4, (128,), 1.0)], axis=1)
    wq = np.asarray(gptq_ternary(w))
    assert np.abs(wq[:, 1]).max() > 10 * np.abs(wq[:, 0]).max()


def test_awq_scales_activation_aware():
    """Channels with larger activations get larger scales; grads blocked."""
    x = jnp.concatenate(
        [jnp.ones((16, 8)) * 10.0, jnp.ones((16, 8)) * 0.1], axis=1)
    s = np.asarray(awq_scales(x))
    assert s[:8].min() > s[8:].max()
    g = jax.grad(lambda x: awq_scales(x).sum())(x)
    np.testing.assert_allclose(np.asarray(g), 0.0)


def test_ste_identity_gradient():
    """d/dx ste(x, q(x)) == 1 even though q is piecewise-constant."""
    w = _w(5, (8, 8))
    g = jax.grad(lambda w: jnp.sum(ste(w, absmean_ternary(w))))(w)
    np.testing.assert_allclose(np.asarray(g), 1.0, rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       method=st.sampled_from(["absmean", "block", "gptq", "awq"]))
def test_bitlinear_close_to_exact_matmul(seed, method):
    """8-bit acts x ternary weights is a *coarse* approximation, but the
    bitlinear output must stay correlated with the exact matmul (sanity that
    scales are applied on the right axes)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (32, 128))
    w = jax.random.normal(k2, (128, 64)) * 0.05
    y = np.asarray(bitlinear(x, w, method)).ravel()
    y_ref = np.asarray(x @ w).ravel()
    corr = np.corrcoef(y, y_ref)[0, 1]
    assert corr > 0.75, f"{method}: corr={corr}"


def test_bitlinear_grad_flows_to_both_operands():
    x = _w(6, (4, 64), 1.0)
    w = _w(7, (64, 16))
    gx, gw = jax.grad(
        lambda x, w: jnp.sum(bitlinear(x, w) ** 2), argnums=(0, 1))(x, w)
    assert float(jnp.abs(gx).sum()) > 0
    assert float(jnp.abs(gw).sum()) > 0


def test_act_quant_preserves_shape_and_scale():
    x = _w(8, (3, 5, 64), 4.0)
    q = act_quant_int8(x)
    assert q.shape == x.shape
    # max-magnitude element is preserved exactly per token
    gamma = jnp.max(jnp.abs(x), axis=-1)
    gq = jnp.max(jnp.abs(q), axis=-1)
    np.testing.assert_allclose(np.asarray(gq), np.asarray(gamma), rtol=1e-4)
