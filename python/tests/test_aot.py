# pytest: the AOT registry — the L2<->L3 contract itself.
import re

import jax
import numpy as np
import pytest

from compile import aot, steps
from compile.configs import SIZES, get_config


@pytest.fixture(scope="module")
def registry():
    return aot.build_registry()


def test_registry_covers_every_size_and_kind(registry):
    names = {a["name"] for a in registry.artifacts}
    for size in SIZES:
        for kind in ("lm_train", "teacher_fwd", "bitnet_train",
                     "distill_train", "student_fwd"):
            assert f"{size}_{kind}" in names, f"{size}_{kind} missing"
    for q in ("block", "gptq", "awq"):
        assert f"tiny_distill_train_{q}" in names
    assert "bitlinear_pallas" in names
    assert "tiny_distill_train_tsmall" in names
    assert "tiny_distill_train_tbase" in names


def test_artifact_io_arity_matches_specs(registry):
    """Every registered artifact's in_specs length equals its declared
    input-name list — positional addressing is the whole contract."""
    for a in registry.artifacts:
        assert len(a["in_specs"]) == len(a["meta"]["inputs"]), a["name"]


def test_train_signatures_follow_convention(registry):
    for a in registry.artifacts:
        meta = a["meta"]
        if meta["kind"] in ("lm_train", "bitnet_train"):
            assert meta["inputs"][-4:] == ["step", "lr", "tokens", "labels"]
            assert meta["outputs"][-1] == "loss.total"
            p = (len(meta["inputs"]) - 4) // 3
            assert meta["inputs"][:p] == [n for n in meta["inputs"][:p]]
            assert len(meta["outputs"]) == 3 * p + 1
        elif meta["kind"] == "distill_train":
            assert meta["inputs"][-7:] == ["step", "lr", "lambda", "gamma",
                                           "distill_layer", "tokens", "labels"]
            assert meta["outputs"][-4:] == ["loss.total", "loss.ce",
                                            "loss.ld", "loss.ad"]
            assert meta["teacher_model"] in registry.models


def test_model_keys_resolve(registry):
    for a in registry.artifacts:
        if a["meta"]["model"]:
            assert a["meta"]["model"] in registry.models, a["name"]


def test_model_key_format():
    cfg = get_config("tiny").replace(use_subln=True, quant_method="absmean")
    assert aot.model_key(cfg) == "tiny-subln-absmean"
    tc = steps._teacher_cfg(cfg)
    assert aot.model_key(tc) == "tiny-nosubln-none"


def test_param_specs_in_manifest_match_flat_order(registry):
    """The manifest's per-model param list must equal the flat order the
    step functions use (rust addresses inputs positionally)."""
    for key, model in registry.models.items():
        # rebuild the config and compare
        cfg_d = model["config"]
        base = get_config(cfg_d["name"]).replace(
            use_subln=cfg_d["use_subln"],
            quant_method=cfg_d["quant_method"])
        assert [p["name"] for p in model["params"]] == \
            steps.param_names(base), key


def test_hlo_text_emission_round_trips():
    """to_hlo_text produces parseable HLO with the expected entry shape."""
    import jax.numpy as jnp

    def fn(x):
        return (x @ x.T + 1.0,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4, 8), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "f32[4,8]" in text
    # ids must be small enough for xla_extension 0.5.1 (the whole reason
    # text is the interchange format)
    assert re.search(r"tuple", text)


def test_sizes_are_strictly_increasing():
    sizes = [get_config(s).n_params() for s in ("tiny", "small", "base")]
    assert sizes[0] < sizes[1] < sizes[2]
    assert sizes[2] > 10 * sizes[0], "need a >=10x sweep for Fig. 1"
