# pytest: end-to-end train-step semantics — losses decrease, AdamW sane,
# flat signatures match the manifest emitted by aot.py.
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import steps
from compile.configs import get_config
from compile.model import init_params
from compile.optim import adamw_update


def _flat_state(cfg, seed=0):
    p = init_params(cfg, jax.random.PRNGKey(seed))
    z = {k: jnp.zeros_like(v) for k, v in p.items()}
    return (steps.flatten(p, cfg) + steps.flatten(z, cfg)
            + steps.flatten(z, cfg))


def _batch(cfg, seed=1):
    tok = jax.random.randint(jax.random.PRNGKey(seed), (8, cfg.seq), 0,
                             cfg.vocab)
    labels = jnp.concatenate(
        [tok[:, 1:], jnp.full((8, 1), 0, jnp.int32)], axis=1)
    return tok, labels


def test_adamw_decreases_quadratic():
    p = {"w": jnp.array([3.0, -2.0])}
    m = {"w": jnp.zeros(2)}
    v = {"w": jnp.zeros(2)}
    for step in range(1, 60):
        g = {"w": 2 * p["w"]}
        p, m, v = adamw_update(p, g, m, v, jnp.float32(step),
                               jnp.float32(0.1))
    assert float(jnp.abs(p["w"]).max()) < 0.5


def test_lm_train_loss_decreases():
    cfg = steps._teacher_cfg(get_config("tiny"))
    fn = jax.jit(steps.make_lm_train(cfg))
    flat = _flat_state(cfg)
    tok, lab = _batch(cfg)
    losses = []
    for i in range(12):
        out = fn(*flat, jnp.float32(i + 1), jnp.float32(3e-3), tok, lab)
        flat = list(out[:-1])
        losses.append(float(out[-1]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_bitnet_train_loss_decreases():
    cfg = get_config("tiny").replace(use_subln=True, quant_method="absmean")
    fn = jax.jit(steps.make_bitnet_train(cfg))
    flat = _flat_state(cfg)
    tok, lab = _batch(cfg)
    losses = []
    for i in range(12):
        out = fn(*flat, jnp.float32(i + 1), jnp.float32(3e-3), tok, lab)
        flat = list(out[:-1])
        losses.append(float(out[-1]))
    assert losses[-1] < losses[0] - 0.3, losses


def test_distill_train_all_losses_finite_and_decreasing():
    cfg = get_config("tiny").replace(use_subln=True, quant_method="absmean")
    tc = steps._teacher_cfg(cfg)
    fn = jax.jit(steps.make_distill_train(cfg))
    flat = _flat_state(cfg)
    teacher = steps.flatten(init_params(tc, jax.random.PRNGKey(9)), tc)
    tok, lab = _batch(cfg)
    totals = []
    for i in range(8):
        out = fn(*flat, *teacher, jnp.float32(i + 1), jnp.float32(2e-3),
                 jnp.float32(10.0), jnp.float32(1e5), jnp.int32(3), tok, lab)
        flat = list(out[:-4])
        total, ce, ld, ad = (float(x) for x in out[-4:])
        assert np.isfinite([total, ce, ld, ad]).all()
        assert abs(total - (ce + 10.0 * ld + 1e5 * ad)) < 1e-2 * max(total, 1)
        totals.append(total)
    assert totals[-1] < totals[0]


def test_distill_zero_coeffs_equals_bitnet_ce():
    """With lambda=gamma=0 the distill step's CE matches the bitnet step."""
    cfg = get_config("tiny").replace(use_subln=True, quant_method="absmean")
    tc = steps._teacher_cfg(cfg)
    dfn = jax.jit(steps.make_distill_train(cfg))
    bfn = jax.jit(steps.make_bitnet_train(cfg))
    flat = _flat_state(cfg)
    teacher = steps.flatten(init_params(tc, jax.random.PRNGKey(9)), tc)
    tok, lab = _batch(cfg)
    dout = dfn(*flat, *teacher, jnp.float32(1), jnp.float32(1e-3),
               jnp.float32(0.0), jnp.float32(0.0), jnp.int32(0), tok, lab)
    bout = bfn(*flat, jnp.float32(1), jnp.float32(1e-3), tok, lab)
    np.testing.assert_allclose(float(dout[-3]), float(bout[-1]), rtol=1e-5)


@pytest.mark.skipif(not os.path.exists(
    os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="run `make artifacts` first")
def test_manifest_signatures_match_steps():
    """The manifest's positional IO contract agrees with the live functions."""
    root = os.path.join(os.path.dirname(__file__), "../..")
    with open(os.path.join(root, "artifacts/manifest.json")) as f:
        man = json.load(f)
    art = man["artifacts"]["tiny_distill_train"]
    cfg = get_config("tiny").replace(use_subln=True, quant_method="absmean")
    tc = steps._teacher_cfg(cfg)
    P, Pt = len(steps.param_names(cfg)), len(steps.param_names(tc))
    assert len(art["inputs"]) == 3 * P + Pt + 7
    assert art["inputs"][-2:] == ["tokens", "labels"]
    assert art["outputs"][-4:] == ["loss.total", "loss.ce", "loss.ld",
                                   "loss.ad"]
    model = man["models"][art["model"]]
    assert [p["name"] for p in model["params"]] == steps.param_names(cfg)
    assert sum(int(np.prod(p["shape"])) for p in model["params"]) == \
        cfg.n_params()
