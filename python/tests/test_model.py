# pytest: L2 model semantics — shapes, SubLN effect, causality, scan=unroll.
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import get_config
from compile.model import forward, init_params, param_specs, rmsnorm
from compile import steps


def _setup(size="tiny", **kw):
    cfg = get_config(size).replace(**kw)
    p = init_params(cfg, jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, cfg.seq), 0,
                             cfg.vocab)
    return cfg, p, tok


@pytest.mark.parametrize("size", ["tiny", "gemmaish", "qwenish"])
def test_forward_shapes(size):
    cfg, p, tok = _setup(size)
    logits, qkv = forward(p, tok, cfg, quant=False,
                          distill_layer=jnp.int32(1))
    assert logits.shape == (2, cfg.seq, cfg.vocab)
    assert qkv.shape == (3, 2, cfg.n_heads, cfg.seq, cfg.head_dim)
    assert np.isfinite(np.asarray(logits)).all()


def test_param_count_matches_config():
    for size in ("tiny", "small", "base", "gemmaish", "qwenish"):
        cfg = get_config(size)
        total = sum(int(np.prod(s)) for _, s, _ in param_specs(cfg))
        assert total == cfg.n_params(), size


def test_causality():
    """Perturbing a future token never changes past logits."""
    cfg, p, tok = _setup()
    logits, _ = forward(p, tok, cfg, quant=False, distill_layer=jnp.int32(-1))
    tok2 = tok.at[:, 64].set((tok[:, 64] + 5) % cfg.vocab)
    logits2, _ = forward(p, tok2, cfg, quant=False,
                         distill_layer=jnp.int32(-1))
    np.testing.assert_allclose(np.asarray(logits[:, :64]),
                               np.asarray(logits2[:, :64]), atol=1e-5)
    assert np.abs(np.asarray(logits[:, 64:]) -
                  np.asarray(logits2[:, 64:])).max() > 1e-4


def test_subln_stabilizes_hidden_variance():
    """Paper §3.1: with ternary weights, SubLN bounds the pre-projection
    activation scale. We check the quantized forward stays finite and that
    SubLN actually changes the computation."""
    cfg, p, tok = _setup(use_subln=True, quant_method="absmean")
    l1, _ = forward(p, tok, cfg, quant=True, distill_layer=jnp.int32(-1))
    cfg2 = cfg.replace(use_subln=False)
    p2 = {k: v for k, v in p.items() if not k.startswith("blocks.subln")}
    l2, _ = forward(p2, tok, cfg2, quant=True, distill_layer=jnp.int32(-1))
    assert np.isfinite(np.asarray(l1)).all()
    assert np.abs(np.asarray(l1) - np.asarray(l2)).max() > 1e-4


def test_subln_ones_is_pure_rmsnorm():
    """With unit gains, SubLN == RMSNorm of the pre-projection tensor."""
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 16)) * 7.0
    y = rmsnorm(x, jnp.ones(16), 1e-6)
    rms = np.sqrt(np.mean(np.asarray(y) ** 2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


def test_distill_layer_capture_selects_layer():
    """qkv_acc holds exactly the requested layer's states."""
    cfg, p, tok = _setup()
    caps = []
    for dl in range(cfg.n_layers):
        _, qkv = forward(p, tok, cfg, quant=False,
                         distill_layer=jnp.int32(dl))
        caps.append(np.asarray(qkv))
    for a in range(cfg.n_layers):
        for b in range(a + 1, cfg.n_layers):
            assert np.abs(caps[a] - caps[b]).max() > 1e-6
    _, none = forward(p, tok, cfg, quant=False, distill_layer=jnp.int32(-1))
    np.testing.assert_allclose(np.asarray(none), 0.0)


def test_quant_forward_differs_from_fp():
    cfg, p, tok = _setup()
    lq, _ = forward(p, tok, cfg, quant=True, distill_layer=jnp.int32(-1))
    lf, _ = forward(p, tok, cfg, quant=False, distill_layer=jnp.int32(-1))
    assert np.abs(np.asarray(lq) - np.asarray(lf)).max() > 1e-4


def test_tied_untied_head():
    cfg, p, tok = _setup("gemmaish")  # untied
    assert "lm_head" in p
    cfg2, p2, _ = _setup("tiny")  # tied
    assert "lm_head" not in p2
