"""Model/architecture configurations for the BitDistill reproduction.

The paper fine-tunes Qwen3 {0.6B, 1.7B, 4B} (plus Gemma3-1B / Qwen2.5-0.5B
backbones). This testbed is a single CPU core, so we reproduce the *scaling
trend* over a ~15x parameter sweep of Qwen3-shaped models (see
DESIGN.md #Hardware-adaptation):

    tiny  ~ 1.0M  (stands in for Qwen3-0.6B)
    small ~ 4.9M  (stands in for Qwen3-1.7B)
    base  ~14.9M  (stands in for Qwen3-4B)

plus two alternative-backbone shapes for Table 3:

    gemmaish  — GeLU MLP, untied LM head, wider FFN ratio (Gemma3-1B analog)
    qwenish   — MQA (1 KV head), larger head_dim (Qwen2.5-0.5B analog)
"""

import dataclasses
from typing import Optional

VOCAB = 1024
SEQ = 128
BATCH = 8


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture of one transformer variant.

    `use_subln` corresponds to the paper's Stage-1 modeling refinement
    (eq. 4-5): RMS SubLN inserted before the attention output projection and
    before the FFN down projection.  `quant_method` selects the weight
    quantizer used in the QAT forward (Table 4):
      - "none"    : full-precision (the FP16 teacher / FP16-SFT baseline)
      - "absmean" : per-tensor ternary, paper eq. (1)-(2)
      - "block"   : per-64-row-block ternary (Block-Quant analog)
      - "gptq"    : per-output-channel ternary scale (GPTQ analog)
      - "awq"     : activation-aware scaled ternary (AWQ analog)
    """

    name: str = "tiny"
    vocab: int = VOCAB
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    n_kv_heads: int = 2
    head_dim: int = 32
    d_ff: int = 384
    act: str = "silu"  # "silu" | "gelu"
    tie_embeddings: bool = True
    use_subln: bool = True
    quant_method: str = "absmean"
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    seq: int = SEQ

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def n_params(self) -> int:
        d, L = self.d_model, self.n_layers
        per_layer = (
            d * self.q_dim  # wq
            + 2 * d * self.kv_dim  # wk, wv
            + self.q_dim * d  # wo
            + 3 * d * self.d_ff  # gate, up, down
            + 2 * d  # attn_norm, ffn_norm
        )
        if self.use_subln:
            per_layer += self.q_dim + self.d_ff
        total = L * per_layer + self.vocab * d + d  # embed + final_norm
        if not self.tie_embeddings:
            total += d * self.vocab
        return total


SIZES = {
    "tiny": ModelConfig(name="tiny", d_model=128, n_layers=4, n_heads=4,
                        n_kv_heads=2, head_dim=32, d_ff=384),
    "small": ModelConfig(name="small", d_model=256, n_layers=6, n_heads=8,
                         n_kv_heads=4, head_dim=32, d_ff=768),
    "base": ModelConfig(name="base", d_model=384, n_layers=8, n_heads=8,
                        n_kv_heads=4, head_dim=48, d_ff=1152),
    # Table 3 alternative backbones (at tiny-ish scale).
    "gemmaish": ModelConfig(name="gemmaish", d_model=128, n_layers=4,
                            n_heads=4, n_kv_heads=4, head_dim=32, d_ff=512,
                            act="gelu", tie_embeddings=False),
    "qwenish": ModelConfig(name="qwenish", d_model=128, n_layers=4,
                           n_heads=2, n_kv_heads=1, head_dim=64, d_ff=384),
}


def get_config(name: str) -> ModelConfig:
    if name not in SIZES:
        raise KeyError(f"unknown model size {name!r}; have {sorted(SIZES)}")
    return SIZES[name]
