"""Pure-jnp oracle for the W1.58A8 BitLinear kernel (paper §2, eq. (1)-(3)).

This is the CORE correctness signal for the Layer-1 pallas kernel: pytest
asserts `bitlinear_pallas(x, w) == bitlinear_ref(x, w)` over hypothesis-swept
shapes/seeds. Keep this file boring and literal — it transcribes the paper's
equations with no fusion tricks.
"""

import jax.numpy as jnp

EPS = 1e-6


def absmean_ref(w, eps=EPS):
    """Eq. (1)-(2): W_q = Delta * RoundClip(W / (Delta + eps), -1, 1),
    Delta = mean(|W|). Returns (dequantized weights, Delta)."""
    delta = jnp.mean(jnp.abs(w))
    q = jnp.clip(jnp.round(w / (delta + eps)), -1.0, 1.0)
    return q * delta, delta


def act_quant_ref(x, eps=EPS):
    """Eq. (3): per-token absmax int8:
    Q(x) = gamma/127 * RoundClip(127/(gamma+eps) * x, -128, 127)."""
    gamma = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    q = jnp.clip(jnp.round(x * (127.0 / (gamma + eps))), -128.0, 127.0)
    return q * (gamma / 127.0)


def bitlinear_ref(x, w, eps=EPS):
    """y = Q_int8(x) @ Q_w(w) — the inference-time BitLinear function."""
    wq, _ = absmean_ref(w, eps)
    xq = act_quant_ref(x, eps)
    return xq @ wq
