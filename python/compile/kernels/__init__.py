# L1: Pallas kernel(s) for the paper's compute hot-spot.
from .bitlinear import bitlinear_pallas, vmem_bytes  # noqa: F401
from .ref import absmean_ref, act_quant_ref, bitlinear_ref  # noqa: F401
