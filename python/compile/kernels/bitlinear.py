"""Layer-1 Pallas kernel: fused W1.58A8 BitLinear matmul.

The paper's compute hot-spot is the BitLinear layer: per-token int8
activation quantization x per-tensor ternary weight quantization x matmul
x dequant rescale, all of which fuse into a single tiled kernel.

Hardware adaptation (DESIGN.md #Hardware-adaptation): the paper's deployment
kernel is a CPU/GPU lookup-table kernel (bitnet.cpp). On TPU the same insight
maps to: keep the (block_m, K) activation tile and the (K, block_n) weight
tile resident in VMEM, quantize in-register, and feed the MXU with the
dequant folded into two cheap VPU rescales (per-row gamma, per-tensor Delta)
after the matmul. BlockSpec expresses the HBM->VMEM schedule that a CUDA
version would express with threadblocks.

interpret=True is mandatory here: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute. Numerical correctness is
validated against kernels/ref.py; TPU-side VMEM/MXU budgets are analyzed
statically in DESIGN.md §7.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-6


def _bitlinear_kernel(x_ref, w_ref, delta_ref, o_ref):
    """One (block_m, block_n) output tile.

    x_ref:     [block_m, K]  f32 activations (full K panel)
    w_ref:     [K, block_n]  f32 master weights (full K panel)
    delta_ref: [1, 1]        f32 per-tensor absmean scale (computed outside:
                             it is a global reduction over W, which cannot be
                             tiled into the grid)
    o_ref:     [block_m, block_n] f32 output
    """
    x = x_ref[...]
    # --- per-token int8 activation quantization (eq. 3), in integer grid ---
    gamma = jnp.max(jnp.abs(x), axis=-1, keepdims=True)  # [bm, 1]
    xq = jnp.clip(jnp.round(x * (127.0 / (gamma + EPS))), -128.0, 127.0)
    # --- per-tensor ternary weight quantization (eq. 1-2) ---
    d = delta_ref[0, 0]
    w = w_ref[...]
    wq = jnp.clip(jnp.round(w / (d + EPS)), -1.0, 1.0)
    # --- integer-grid matmul (exact in f32: |acc| << 2^24), then dequant ---
    acc = jnp.dot(xq, wq, preferred_element_type=jnp.float32)
    o_ref[...] = acc * (gamma / 127.0) * d


def _ceil_to(v: int, m: int) -> int:
    return (v + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def bitlinear_pallas(x, w, *, block_m: int = 32, block_n: int = 128):
    """Fused BitLinear y = Q_int8(x) @ Q_absmean(w); x [M, K], w [K, N].

    Shapes need not divide the block sizes: operands are zero-padded (a
    zero row quantizes to zeros — gamma=0 is safe because of the +EPS) and
    the output is sliced back.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    # Compute in f32 regardless of the input dtype (bf16 operands are
    # upcast BEFORE the absmean reduction so Delta matches the f32 oracle).
    x = x.astype(jnp.float32)
    w = w.astype(jnp.float32)
    delta = jnp.mean(jnp.abs(w)).reshape(1, 1)

    mp, np_ = _ceil_to(m, block_m), _ceil_to(n, block_n)
    xp = jnp.pad(x, ((0, mp - m), (0, 0)))
    wp = jnp.pad(w, ((0, 0), (0, np_ - n)))

    grid = (mp // block_m, np_ // block_n)
    out = pl.pallas_call(
        _bitlinear_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xp, wp, delta)
    return out[:m, :n]


def vmem_bytes(block_m: int, block_n: int, k: int) -> int:
    """Static VMEM footprint estimate for one grid step (f32 operands +
    output + int8-grid temporaries), used by the DESIGN.md §7 roofline."""
    f32 = 4
    x_tile = block_m * k * f32
    w_tile = k * block_n * f32
    out_tile = block_m * block_n * f32
    temps = x_tile + w_tile  # xq, wq in-register/VMEM copies
    return x_tile + w_tile + out_tile + temps
