"""Layer-2: the Qwen3-shaped transformer with optional SubLN (paper §3.1).

One forward function serves every model role in the pipeline:

  - teacher / FP16 baseline: ``quant=False`` (plain f32 matmuls, no SubLN)
  - 1.58-bit student:        ``quant=True``  (BitLinear QAT fwd with STE,
                              SubLN per eq. (4)-(5) when cfg.use_subln)

The forward also captures the (Q, K, V) projection states of one layer
(selected at runtime by the ``distill_layer`` scalar input) for the MiniLM
attention-relation distillation loss (paper §3.3, Algorithm 1).

Parameters are a flat dict of stacked-per-layer arrays so that the layer
loop is a ``lax.scan`` — this keeps the lowered HLO compact (a While loop
instead of L inlined blocks) regardless of depth.
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .quantizers import bitlinear

# Parameter names, in the canonical (manifest) order. Stacked block params
# carry a leading n_layers dim.
BLOCK_PARAM_SHAPES = {
    "attn_norm": lambda c: (c.d_model,),
    "wq": lambda c: (c.d_model, c.q_dim),
    "wk": lambda c: (c.d_model, c.kv_dim),
    "wv": lambda c: (c.d_model, c.kv_dim),
    "subln_attn": lambda c: (c.q_dim,),
    "wo": lambda c: (c.q_dim, c.d_model),
    "ffn_norm": lambda c: (c.d_model,),
    "w_gate": lambda c: (c.d_model, c.d_ff),
    "w_up": lambda c: (c.d_model, c.d_ff),
    "subln_ffn": lambda c: (c.d_ff,),
    "w_down": lambda c: (c.d_ff, c.d_model),
}


def param_specs(cfg: ModelConfig):
    """[(name, shape, init)] in canonical order. init: ("normal", std) or
    ("ones",). Residual-output projections get the 1/sqrt(2L) GPT scaling."""
    out = [("embed", (cfg.vocab, cfg.d_model), ("normal", 0.02))]
    resid_scale = 0.02 / (2.0 * cfg.n_layers) ** 0.5
    for name, shape_fn in BLOCK_PARAM_SHAPES.items():
        if name.startswith("subln") and not cfg.use_subln:
            continue
        shape = (cfg.n_layers,) + shape_fn(cfg)
        if name.endswith("norm") or name.startswith("subln"):
            init = ("ones",)
        elif name in ("wo", "w_down"):
            init = ("normal", resid_scale)
        else:
            init = ("normal", 0.02)
        out.append((f"blocks.{name}", shape, init))
    out.append(("final_norm", (cfg.d_model,), ("ones",)))
    if not cfg.tie_embeddings:
        out.append(("lm_head", (cfg.d_model, cfg.vocab), ("normal", 0.02)))
    return out


def init_params(cfg: ModelConfig, key) -> dict:
    """Reference initializer (tests + aot fixtures; rust has its own
    manifest-driven initializer that follows the same spec)."""
    params = {}
    for name, shape, init in param_specs(cfg):
        key, sub = jax.random.split(key)
        if init[0] == "ones":
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            params[name] = jax.random.normal(sub, shape, jnp.float32) * init[1]
    return params


def rmsnorm(x, g, eps):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * g


def rope_tables(cfg: ModelConfig):
    """cos/sin tables [seq, head_dim//2], baked into the HLO as constants."""
    half = cfg.head_dim // 2
    inv_freq = 1.0 / (cfg.rope_theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    t = jnp.arange(cfg.seq, dtype=jnp.float32)
    ang = t[:, None] * inv_freq[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: [B, H, T, hd] with rotate-half pairing (x1, x2) = split(hd/2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1
    )


def _linear(x, w, quant: bool, method: str):
    if quant:
        shp = x.shape
        y = bitlinear(x.reshape(-1, shp[-1]), w, method)
        return y.reshape(*shp[:-1], w.shape[-1])
    return x @ w


def forward(params: dict, tokens, cfg: ModelConfig, quant: bool,
            distill_layer):
    """Run the transformer.

    tokens: i32 [B, T]; distill_layer: i32 scalar (-1 = capture nothing).
    Returns (logits [B, T, vocab], qkv_states [3, B, H, T, hd]) where the
    states are the layer-``distill_layer`` Q/K/V projections (K/V repeated
    to the full head count so GQA students align with any teacher).
    """
    B, T = tokens.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    rep = H // KV
    cos_t, sin_t = rope_tables(cfg)
    cos = cos_t[None, None, :T, :]
    sin = sin_t[None, None, :T, :]
    # iota-comparison causal mask (keeps the HLO text free of a TxT literal)
    causal = jnp.arange(T)[:, None] >= jnp.arange(T)[None, :]
    neg = jnp.float32(-1e9)

    x = params["embed"][tokens]  # [B, T, d]

    block_names = [n for n in BLOCK_PARAM_SHAPES
                   if cfg.use_subln or not n.startswith("subln")]
    stacked = {n: params[f"blocks.{n}"] for n in block_names}

    def body(carry, scanned):
        h, qkv_acc = carry
        p, idx = scanned
        # --- attention (eq. 4 / 6) ---
        a_in = rmsnorm(h, p["attn_norm"], cfg.norm_eps)
        q = _linear(a_in, p["wq"], quant, cfg.quant_method)
        k = _linear(a_in, p["wk"], quant, cfg.quant_method)
        v = _linear(a_in, p["wv"], quant, cfg.quant_method)
        q = q.reshape(B, T, H, hd).transpose(0, 2, 1, 3)
        k = k.reshape(B, T, KV, hd).transpose(0, 2, 1, 3)
        v = v.reshape(B, T, KV, hd).transpose(0, 2, 1, 3)
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
        # capture pre-RoPE projection states for attention-relation KD
        states = jnp.stack([q, k, v])  # [3, B, H, T, hd]
        qkv_acc = jnp.where(idx == distill_layer, states, qkv_acc)
        qr = apply_rope(q, cos, sin)
        kr = apply_rope(k, cos, sin)
        scores = jnp.einsum("bhqd,bhkd->bhqk", qr, kr) / jnp.sqrt(
            jnp.float32(hd))
        scores = jnp.where(causal[None, None], scores, neg)
        attn = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", attn, v)
        o = o.transpose(0, 2, 1, 3).reshape(B, T, H * hd)
        if cfg.use_subln:
            o = rmsnorm(o, p["subln_attn"], cfg.norm_eps)  # eq. (4)
        h = h + _linear(o, p["wo"], quant, cfg.quant_method)
        # --- FFN (eq. 5) ---
        f_in = rmsnorm(h, p["ffn_norm"], cfg.norm_eps)
        gate = _linear(f_in, p["w_gate"], quant, cfg.quant_method)
        up = _linear(f_in, p["w_up"], quant, cfg.quant_method)
        act = jax.nn.silu(gate) if cfg.act == "silu" else jax.nn.gelu(gate)
        ff = up * act
        if cfg.use_subln:
            ff = rmsnorm(ff, p["subln_ffn"], cfg.norm_eps)  # eq. (5)
        h = h + _linear(ff, p["w_down"], quant, cfg.quant_method)
        return (h, qkv_acc), None

    qkv0 = jnp.zeros((3, B, H, T, hd), jnp.float32)
    (x, qkv), _ = jax.lax.scan(
        body, (x, qkv0), (stacked, jnp.arange(cfg.n_layers)))

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head  # LM head kept full-precision (see DESIGN.md)
    return logits, qkv
