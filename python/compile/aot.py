"""AOT driver: lower every (model x step-kind) to HLO **text** artifacts.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage (from python/):
    python -m compile.aot --out ../artifacts [--only REGEX] [--list]

Emits artifacts/<name>.hlo.txt + artifacts/manifest.json. The manifest is
the L2<->L3 contract: every executable's positional input/output signature,
plus per-model parameter specs (shape, init, weight-decay flag) that the
rust side uses to initialize and checkpoint parameters.
"""

import argparse
import json
import os
import re
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .configs import BATCH, SEQ, VOCAB, ModelConfig, get_config
from . import steps
from .model import param_specs

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _param_io(cfg: ModelConfig, prefix: str):
    """(specs, names) for one flat param group."""
    sp, names = [], []
    for name, shape, _ in param_specs(cfg):
        sp.append(spec(shape))
        names.append(f"{prefix}.{name}")
    return sp, names


def _opt_io(cfg: ModelConfig):
    specs_, names = [], []
    for g in ("m", "v"):
        s, n = _param_io(cfg, g)
        specs_ += s
        names += n
    return specs_, names


def _scalar(name, dtype=F32):
    return spec((), dtype), name


def model_key(cfg: ModelConfig) -> str:
    """Manifest key for a concrete model variant."""
    bits = [cfg.name, "subln" if cfg.use_subln else "nosubln", cfg.quant_method]
    return "-".join(bits)


class Registry:
    def __init__(self):
        self.models = {}
        self.artifacts = []

    def model(self, cfg: ModelConfig) -> str:
        key = model_key(cfg)
        if key not in self.models:
            self.models[key] = {
                "config": {
                    "name": cfg.name, "vocab": cfg.vocab,
                    "d_model": cfg.d_model, "n_layers": cfg.n_layers,
                    "n_heads": cfg.n_heads, "n_kv_heads": cfg.n_kv_heads,
                    "head_dim": cfg.head_dim, "d_ff": cfg.d_ff,
                    "act": cfg.act, "tie_embeddings": cfg.tie_embeddings,
                    "use_subln": cfg.use_subln,
                    "quant_method": cfg.quant_method,
                    "rope_theta": cfg.rope_theta, "norm_eps": cfg.norm_eps,
                    "seq": cfg.seq,
                },
                "n_params": cfg.n_params(),
                "params": [
                    {
                        "name": name,
                        "shape": list(shape),
                        "init": {"kind": init[0],
                                 "std": (init[1] if init[0] == "normal" else 0.0)},
                        "weight_decay": len(shape) >= 2,
                    }
                    for name, shape, init in param_specs(cfg)
                ],
            }
        return key

    def add(self, name, fn, in_specs, in_names, out_names, model_key_,
            kind, extra=None):
        self.artifacts.append({
            "name": name, "fn": fn, "in_specs": in_specs,
            "meta": {
                "name": name, "file": f"{name}.hlo.txt", "kind": kind,
                "model": model_key_, "batch": BATCH, "seq": SEQ,
                "inputs": in_names, "outputs": out_names,
                **(extra or {}),
            },
        })


def build_registry() -> Registry:
    reg = Registry()
    tok = spec((BATCH, SEQ), I32)
    lab = spec((BATCH, SEQ), I32)

    def add_train(name, cfg, kind, teacher=None):
        """Register a train-step artifact. kind: lm|bitnet|distill."""
        p_specs, p_names = _param_io(cfg, "param")
        o_specs, o_names = _opt_io(cfg)
        mkey = reg.model(cfg)
        if kind == "distill":
            tcfg = steps._teacher_cfg(teacher if teacher else cfg)
            t_specs, t_names = _param_io(tcfg, "teacher")
            tkey = reg.model(tcfg)
            fn = steps.make_distill_train(cfg, teacher)
            in_specs = (p_specs + o_specs + t_specs
                        + [spec((), F32)] * 4 + [spec((), I32), tok, lab])
            in_names = (p_names + o_names + t_names
                        + ["step", "lr", "lambda", "gamma",
                           "distill_layer", "tokens", "labels"])
            out_names = p_names + o_names + ["loss.total", "loss.ce",
                                             "loss.ld", "loss.ad"]
            reg.add(name, fn, in_specs, in_names, out_names, mkey,
                    "distill_train", {"teacher_model": tkey})
        else:
            fn = (steps.make_lm_train(cfg) if kind == "lm"
                  else steps.make_bitnet_train(cfg))
            in_specs = p_specs + o_specs + [spec((), F32)] * 2 + [tok, lab]
            in_names = p_names + o_names + ["step", "lr", "tokens", "labels"]
            out_names = p_names + o_names + ["loss.total"]
            reg.add(name, fn, in_specs, in_names, out_names, mkey,
                    f"{kind}_train")

    def add_fwd(name, cfg, quant):
        p_specs, p_names = _param_io(cfg, "param")
        mkey = reg.model(cfg)
        fn = steps.make_fwd(cfg, quant)
        reg.add(name, fn, p_specs + [tok], p_names + ["tokens"],
                ["logits"], mkey, "fwd")

    for size in ("tiny", "small", "base", "gemmaish", "qwenish"):
        cfg = get_config(size)
        student = cfg.replace(use_subln=True, quant_method="absmean")
        teacher = steps._teacher_cfg(cfg)
        add_train(f"{size}_lm_train", teacher, "lm")
        add_fwd(f"{size}_teacher_fwd", teacher, quant=False)
        add_train(f"{size}_bitnet_train", student, "bitnet")
        add_train(f"{size}_distill_train", student, "distill")
        add_fwd(f"{size}_student_fwd", student, quant=True)

    # --- tiny ablation variants -------------------------------------------
    tiny = get_config("tiny")
    nosub = tiny.replace(use_subln=False, quant_method="absmean")
    add_train("tiny_bitnet_train_nosubln", nosub, "bitnet")
    add_train("tiny_distill_train_nosubln", nosub, "distill")
    add_fwd("tiny_student_fwd_nosubln", nosub, quant=True)

    # --- Table 4: quantizer variants --------------------------------------
    for q in ("block", "gptq", "awq"):
        qcfg = tiny.replace(use_subln=True, quant_method=q)
        add_train(f"tiny_bitnet_train_{q}", qcfg, "bitnet")
        add_train(f"tiny_distill_train_{q}", qcfg, "distill")
        add_fwd(f"tiny_student_fwd_{q}", qcfg, quant=True)

    # --- Fig. 3c: bigger teachers for the tiny student --------------------
    st = tiny.replace(use_subln=True, quant_method="absmean")
    for tsize in ("small", "base"):
        add_train(f"tiny_distill_train_t{tsize}", st, "distill",
                  teacher=get_config(tsize))

    # --- L1 composition proof: the pallas kernel as its own artifact ------
    from .kernels import bitlinear_pallas
    reg.add("bitlinear_pallas",
            lambda x, w: (bitlinear_pallas(x, w),),
            [spec((64, 128)), spec((128, 256))], ["x", "w"], ["y"],
            "", "kernel")
    return reg


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="regex: build only matching artifact names")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    reg = build_registry()
    if args.list:
        for a in reg.artifacts:
            print(a["name"])
        return

    os.makedirs(args.out, exist_ok=True)
    pat = re.compile(args.only) if args.only else None
    manifest_path = os.path.join(args.out, "manifest.json")
    built = 0
    t0 = time.time()
    for a in reg.artifacts:
        if pat and not pat.search(a["name"]):
            continue
        path = os.path.join(args.out, a["meta"]["file"])
        t1 = time.time()
        lowered = jax.jit(a["fn"]).lower(*a["in_specs"])
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        built += 1
        print(f"[aot] {a['name']}: {len(text)/1e6:.2f} MB "
              f"({time.time()-t1:.1f}s)", flush=True)

    manifest = {
        "vocab": VOCAB, "batch": BATCH, "seq": SEQ,
        "models": reg.models,
        "artifacts": {a["name"]: a["meta"] for a in reg.artifacts},
    }
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] built {built} artifacts in {time.time()-t0:.0f}s "
          f"-> {args.out}", flush=True)


if __name__ == "__main__":
    main()
