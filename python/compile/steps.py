"""Jitted step functions with FLAT argument signatures.

The rust driver addresses executable inputs positionally, so every step is
built over a flat tuple of arrays whose order is recorded in
artifacts/manifest.json. Helper `flatten`/`unflatten` map between the flat
tuple and the named param dict in canonical `param_specs` order.

Step kinds (see DESIGN.md §5):
  lm_train      f32 model   CE only      (pretraining, teacher SFT, FP16-SFT)
  bitnet_train  QAT student CE only      (BitNet-SFT baseline, stage-2 CT)
  distill_train QAT student CE+LD+AD     (stage-3; teacher params are inputs)
  fwd           logits forward           (eval + rust-engine parity tests)
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .losses import attention_relation_loss, ce_loss, logits_kd_loss
from .model import forward, param_specs
from .optim import adamw_update

TAU = 5.0  # logits-distillation temperature (paper §4.1)


def param_names(cfg: ModelConfig):
    return [name for name, _, _ in param_specs(cfg)]


def flatten(d: dict, cfg: ModelConfig):
    return [d[n] for n in param_names(cfg)]


def unflatten(flat, cfg: ModelConfig) -> dict:
    return dict(zip(param_names(cfg), flat))


def _teacher_cfg(cfg: ModelConfig) -> ModelConfig:
    """The FP16 teacher keeps the original architecture: no SubLN, no quant."""
    return cfg.replace(use_subln=False, quant_method="none")


def make_lm_train(cfg: ModelConfig):
    """f32 CE train step: (P params, P m, P v, step, lr, tokens, labels)
    -> (P params, P m, P v, loss)."""
    P = len(param_names(cfg))

    def step_fn(*flat):
        params = unflatten(flat[:P], cfg)
        m = unflatten(flat[P:2 * P], cfg)
        v = unflatten(flat[2 * P:3 * P], cfg)
        step, lr, tokens, labels = flat[3 * P:]

        def loss_fn(p):
            logits, _ = forward(p, tokens, cfg, quant=False,
                                distill_layer=jnp.int32(-1))
            return ce_loss(logits, labels)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, m, v = adamw_update(params, grads, m, v, step, lr)
        return tuple(flatten(params, cfg) + flatten(m, cfg)
                     + flatten(v, cfg) + [loss])

    return step_fn


def make_bitnet_train(cfg: ModelConfig):
    """QAT (STE) CE-only train step for the 1.58-bit student. Same flat
    signature as lm_train. Used for the BitNet-SFT baseline and the
    stage-2 continual pre-training of BitDistill."""
    P = len(param_names(cfg))

    def step_fn(*flat):
        params = unflatten(flat[:P], cfg)
        m = unflatten(flat[P:2 * P], cfg)
        v = unflatten(flat[2 * P:3 * P], cfg)
        step, lr, tokens, labels = flat[3 * P:]

        def loss_fn(p):
            logits, _ = forward(p, tokens, cfg, quant=True,
                                distill_layer=jnp.int32(-1))
            return ce_loss(logits, labels)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, m, v = adamw_update(params, grads, m, v, step, lr)
        return tuple(flatten(params, cfg) + flatten(m, cfg)
                     + flatten(v, cfg) + [loss])

    return step_fn


def make_distill_train(cfg: ModelConfig, teacher: ModelConfig = None):
    """Stage-3 step: CE + lambda*LD + gamma*AD (eq. 13).

    Inputs: (P student params, P m, P v, Pt teacher params, step, lr,
             lam, gam, distill_layer i32, tokens, labels)
    Outputs: (P params, P m, P v, total, ce, ld, ad).

    lambda/gamma/distill_layer are runtime scalars so one artifact serves
    classification (lam=10, gam=1e5), summarization (lam=1, gam=1e3), the
    Table-6 LD/AD ablations (coefficient = 0) and the Fig-3b layer sweep.
    `teacher` may be a *larger* config (Fig. 3c better-teacher sweep).
    """
    tc = _teacher_cfg(teacher if teacher is not None else cfg)
    P = len(param_names(cfg))
    Pt = len(param_names(tc))

    def step_fn(*flat):
        params = unflatten(flat[:P], cfg)
        m = unflatten(flat[P:2 * P], cfg)
        v = unflatten(flat[2 * P:3 * P], cfg)
        teacher = unflatten(flat[3 * P:3 * P + Pt], tc)
        step, lr, lam, gam, dl, tokens, labels = flat[3 * P + Pt:]

        # Map the student's distill layer onto the (possibly deeper) teacher
        # proportionally: layer i of Ls corresponds to layer
        # (i+1)*Lt/Ls - 1 of Lt (identity when the depths match).
        t_dl = (dl + 1) * tc.n_layers // cfg.n_layers - 1
        t_logits, t_states = forward(teacher, tokens, tc, quant=False,
                                     distill_layer=t_dl)
        t_logits = jax.lax.stop_gradient(t_logits)
        t_states = jax.lax.stop_gradient(t_states)

        def loss_fn(p):
            s_logits, s_states = forward(p, tokens, cfg, quant=True,
                                         distill_layer=dl)
            ce = ce_loss(s_logits, labels)
            ld = logits_kd_loss(t_logits, s_logits, labels, TAU)
            ad = attention_relation_loss(t_states, s_states,
                                         split_heads=cfg.n_heads)
            total = ce + lam * ld + gam * ad
            return total, (ce, ld, ad)

        (total, (ce, ld, ad)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params, m, v = adamw_update(params, grads, m, v, step, lr)
        return tuple(flatten(params, cfg) + flatten(m, cfg)
                     + flatten(v, cfg) + [total, ce, ld, ad])

    return step_fn


def make_fwd(cfg: ModelConfig, quant: bool):
    """Logits forward: (P params, tokens) -> (logits,)."""
    P = len(param_names(cfg))

    def fwd_fn(*flat):
        params = unflatten(flat[:P], cfg)
        tokens = flat[P]
        logits, _ = forward(params, tokens, cfg, quant=quant,
                            distill_layer=jnp.int32(-1))
        return (logits,)

    return fwd_fn
