"""Losses for the BitDistill Stage-3 objective (paper §3.3, eq. (8)-(14)).

    L = L_CE + lambda * L_LD + gamma * L_AD

Label convention: i32 labels aligned with logits positions; -100 = ignored
(prompt / padding). The rust data layer produces already-shifted labels, so
the model never shifts internally — the same CE works for LM continual
pre-training (stage 2) and downstream SFT.
"""

import jax
import jax.numpy as jnp

IGNORE = -100


def ce_loss(logits, labels):
    """Eq. (14): mean cross-entropy over non-ignored positions."""
    mask = labels != IGNORE
    safe = jnp.where(mask, labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    tok = jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    n = jnp.maximum(jnp.sum(mask), 1)
    return -jnp.sum(jnp.where(mask, tok, 0.0)) / n


def logits_kd_loss(teacher_logits, student_logits, labels, tau: float):
    """Eq. (8)-(9): KL(P_teacher^tau || P_student^tau) on supervised
    positions, mean over those positions."""
    mask = labels != IGNORE
    tl = jax.nn.log_softmax(teacher_logits / tau, axis=-1)
    sl = jax.nn.log_softmax(student_logits / tau, axis=-1)
    kl = jnp.sum(jnp.exp(tl) * (tl - sl), axis=-1)  # [B, T]
    n = jnp.maximum(jnp.sum(mask), 1)
    return jnp.sum(jnp.where(mask, kl, 0.0)) / n


def _relation_logprobs(states_i, split_heads: int):
    """TxT relation matrix of one Q/K/V tensor [B, H, T, hd]: regroup heads
    into `split_heads` relation heads of dim D = H*hd/split_heads,
    L2-normalize, scaled dot-product by sqrt(D) (the `temperature` of
    Algorithm 1 / sqrt(d_r) of eq. (12)), log-softmax over keys."""
    B, H, T, hd = states_i.shape
    assert (H * hd) % split_heads == 0
    D = H * hd // split_heads
    v = states_i.transpose(0, 2, 1, 3)           # [B, T, H, hd]
    v = v.reshape(B, T, split_heads, D)
    v = v.transpose(0, 2, 1, 3)                  # [B, split, T, D]
    v = v / jnp.maximum(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-8)
    rel = jnp.einsum("bstd,bsud->bstu", v, v) / jnp.sqrt(jnp.float32(D))
    return jax.nn.log_softmax(rel, axis=-1)      # [B, split, T, T]


def attention_relation_loss(teacher_states, student_states,
                            split_heads: int):
    """Eq. (10)-(12) / Algorithm 1: MiniLM multi-head attention relation KD.

    states: [3, B, H, T, hd] — the Q/K/V projections of the distilled layer
    (K/V repeated to the full head count). Teacher and student may differ in
    (H, hd) — the relation matrices are TxT regardless, which is exactly how
    MiniLM transfers across widths (Fig. 3c teacher-size sweep). KL with
    batchmean reduction; alpha_i = 1 for all relations (paper §4.1).
    """
    _, B, _, T, _ = student_states.shape
    total = 0.0
    for i in range(3):  # Q, K, V relations
        tl = _relation_logprobs(teacher_states[i], split_heads)
        sl = _relation_logprobs(student_states[i], split_heads)
        t_prob = jnp.exp(tl)
        kl = jnp.sum(t_prob * (tl - sl), axis=-1)    # [B, split, T]
        total = total + jnp.sum(kl) / (B * split_heads * T)
    return total
