"""W1.58A8 quantizers (paper §2) + the Table-4 weight-quantizer variants.

All functions are differentiable via the straight-through estimator (STE,
[BLC13]): q(x) is computed exactly in the forward pass while the backward
pass sees identity, i.e. ``ste(x, q) = x + stop_grad(q - x)``.
"""

import jax
import jax.numpy as jnp

EPS = 1e-6
BLOCK = 64  # row-block size for the Block-Quant analog


def ste(x: jax.Array, q: jax.Array) -> jax.Array:
    """Straight-through estimator: forward q, backward identity."""
    return x + jax.lax.stop_gradient(q - x)


# ---------------------------------------------------------------------------
# Weight quantizers -> ternary {-1, 0, 1} * scale
# ---------------------------------------------------------------------------

def absmean_ternary(w: jax.Array, eps: float = EPS) -> jax.Array:
    """Paper eq. (1)-(2): per-tensor absmean ternary quantization."""
    delta = jnp.mean(jnp.abs(w))
    q = jnp.clip(jnp.round(w / (delta + eps)), -1.0, 1.0)
    return q * delta


def block_ternary(w: jax.Array, eps: float = EPS, block: int = BLOCK) -> jax.Array:
    """Block-Quant analog [DLSZ21]: absmean ternary per contiguous row block.

    The input dimension (axis 0) is split into blocks of `block` rows; each
    (block, N) tile gets its own Delta. All model dims are multiples of 64.
    """
    k, n = w.shape
    assert k % block == 0, f"in-dim {k} not divisible by block {block}"
    wb = w.reshape(k // block, block, n)
    delta = jnp.mean(jnp.abs(wb), axis=(1, 2), keepdims=True)
    q = jnp.clip(jnp.round(wb / (delta + eps)), -1.0, 1.0)
    return (q * delta).reshape(k, n)


def gptq_ternary(w: jax.Array, eps: float = EPS) -> jax.Array:
    """GPTQ analog [FAHA22]: per-output-channel ternary scale.

    Full GPTQ is a Hessian-compensated PTQ; inside a QAT forward the
    distinguishing property is the finer (per-column) scale grid, which is
    what we keep (see DESIGN.md #Hardware-adaptation).
    """
    delta = jnp.mean(jnp.abs(w), axis=0, keepdims=True)
    q = jnp.clip(jnp.round(w / (delta + eps)), -1.0, 1.0)
    return q * delta


def awq_scales(x: jax.Array, eps: float = EPS) -> jax.Array:
    """AWQ analog [LTT+24]: activation-aware per-input-channel scales.

    s_k = sqrt(mean_t |x_{t,k}|), clipped away from zero. Gradients do not
    flow through the scales (they are statistics, not parameters).
    """
    flat = x.reshape(-1, x.shape[-1])
    s = jnp.sqrt(jnp.mean(jnp.abs(flat), axis=0) + eps)
    s = jnp.maximum(s, 1e-3)
    return jax.lax.stop_gradient(s)


def quantize_weight(w: jax.Array, method: str, eps: float = EPS) -> jax.Array:
    """Dispatch on the Table-4 quantizer family (AWQ is handled in bitlinear
    because it also rescales the activations)."""
    if method in ("absmean", "awq"):
        return absmean_ternary(w, eps)
    if method == "block":
        return block_ternary(w, eps)
    if method == "gptq":
        return gptq_ternary(w, eps)
    raise ValueError(f"unknown quant method {method!r}")


# ---------------------------------------------------------------------------
# Activation quantizer -> int8 grid (paper eq. (3))
# ---------------------------------------------------------------------------

def act_quant_int8(x: jax.Array, eps: float = EPS) -> jax.Array:
    """Per-token absmax int8 activation quantization, returned dequantized:
    Q(x) = (gamma/127) * RoundClip(127/(gamma+eps) * x, -128, 127)."""
    gamma = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    q = jnp.clip(jnp.round(x * (127.0 / (gamma + eps))), -128.0, 127.0)
    return q * (gamma / 127.0)


# ---------------------------------------------------------------------------
# The QAT BitLinear forward (jnp path; the pallas kernel in
# kernels/bitlinear.py computes the identical inference-time function)
# ---------------------------------------------------------------------------

def bitlinear(x: jax.Array, w: jax.Array, method: str = "absmean") -> jax.Array:
    """y = Q_int8(x) @ Q_w(w), with STE on both quantizers.

    x: [..., K]; w: [K, N]. For "awq", activations are divided by the
    activation-aware scales and the weights multiplied by them before
    ternarization (mathematically a similarity rescaling of the matmul).
    """
    if method == "awq":
        s = awq_scales(x)
        x = x / s
        w = w * s[:, None]
    qw = ste(w, quantize_weight(w, method))
    qx = ste(x, act_quant_int8(x))
    return qx @ qw
