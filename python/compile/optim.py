"""AdamW, written from scratch (the image has no optax).

State = (m, v) pytrees matching params + a step scalar supplied by the
rust driver (which also owns the LR schedule — lr arrives as a scalar
input of the train-step executable, so schedules never require re-lowering).

Weight decay follows the usual LLM convention: applied only to matrices
(ndim >= 2), not to norm gains.
"""

import jax
import jax.numpy as jnp

B1 = 0.9
B2 = 0.95
EPS = 1e-8
WD = 0.01


def adamw_update(params: dict, grads: dict, m: dict, v: dict, step, lr):
    """One AdamW step. `step` is the 1-based f32 step counter."""
    bc1 = 1.0 - B1 ** step
    bc2 = 1.0 - B2 ** step
    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        g = grads[k]
        m_k = B1 * m[k] + (1.0 - B1) * g
        v_k = B2 * v[k] + (1.0 - B2) * g * g
        upd = (m_k / bc1) / (jnp.sqrt(v_k / bc2) + EPS)
        p = params[k]
        if p.ndim >= 2:
            upd = upd + WD * p
        new_p[k] = p - lr * upd
        new_m[k] = m_k
        new_v[k] = v_k
    return new_p, new_m, new_v
